"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per instructions: sweep shapes/dtypes per kernel and assert_allclose
against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import (
    beamform,
    beamform_ref,
    decode_attention,
    decode_attention_ref,
    flash_attention,
    flash_attention_custom,
    attention_ref,
    rmsnorm,
    rmsnorm_ref,
    ssd_scan,
    ssd_scan_ref,
    wkv6,
    wkv6_ref,
)
from repro.models.linear_scan import naive_linear_recurrence

TOL = dict(rtol=2e-2, atol=2e-3)  # bf16 inputs, f32 accumulation
TOL32 = dict(rtol=1e-3, atol=1e-3)  # f32: accumulation-order noise near zero


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# --------------------------------------------------------------------------- beamformer
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk,blocks", [
    ((256, 256, 256), dict(bm=128, bn=128, bk=128)),
    ((256, 128, 512), dict(bm=128, bn=128, bk=256)),
])
@pytest.mark.parametrize("karatsuba", [False, True])
def test_beamformer_matches_ref(mnk, blocks, karatsuba, dtype):
    m, n, k = mnk
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    ar, ai = _rand(ks[0], (m, k), dtype), _rand(ks[1], (m, k), dtype)
    br, bi = _rand(ks[2], (k, n), dtype), _rand(ks[3], (k, n), dtype)
    cr, ci = beamform(ar, ai, br, bi, karatsuba=karatsuba, **blocks)
    rr, ri = beamform_ref(ar, ai, br, bi)
    tol = TOL32 if dtype == jnp.float32 else dict(rtol=3e-2, atol=0.5)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(rr), **tol)
    np.testing.assert_allclose(np.asarray(ci), np.asarray(ri), **tol)


# --------------------------------------------------------------------------- flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, Sq, Sk, Hq, Hkv, D)
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 1, 64),   # MQA
    (1, 256, 256, 8, 2, 128),  # GQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, causal, dtype):
    b, sq, sk, hq, hkv, d = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (b, sq, hq, d), dtype)
    k = _rand(ks[1], (b, sk, hkv, d), dtype)
    v = _rand(ks[2], (b, sk, hkv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL
    )


def test_flash_attention_custom_grad_matches_ref():
    b, s, h, d = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (_rand(kk, (b, s, h, d), jnp.float32) for kk in ks)

    def f_kernel(q, k, v):
        return (flash_attention_custom(q, k, v, True) ** 2).sum()

    def f_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------------------- decode attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (B, S, Hq, Hkv, D, kv_lens)
    (2, 512, 4, 4, 64, (100, 512)),
    (2, 1024, 8, 2, 64, (1, 777)),
    (1, 512, 4, 1, 128, (511,)),
])
def test_decode_attention_matches_ref(shape, dtype):
    b, s, hq, hkv, d, lens = shape
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, hq, d), dtype)
    kc = _rand(ks[1], (b, s, hkv, d), dtype)
    vc = _rand(ks[2], (b, s, hkv, d), dtype)
    kv_len = jnp.array(lens[:b], jnp.int32)
    out = decode_attention(q, kc, vc, kv_len, bk=256)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **TOL
    )


def test_decode_attention_kv0_rows_are_exact_zero():
    """Regression: a ``kv_len == 0`` row (a free/padded serve slot) used to
    flush ``acc / l`` with ``l == 0`` — NaN all over the batch row.  The
    contract is exact zeros: nothing to attend to."""
    from repro.kernels.paged_attention import ragged_decode_ref

    b, s, hq, hkv, d = 3, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (b, hq, d), jnp.float32)
    kc = _rand(ks[1], (b, s, hkv, d), jnp.float32)
    vc = _rand(ks[2], (b, s, hkv, d), jnp.float32)
    kv_len = jnp.array([0, 17, 0], jnp.int32)
    out = np.asarray(decode_attention(q, kc, vc, kv_len, bk=128))
    assert np.isfinite(out).all(), "kv_len == 0 row produced NaN/inf"
    assert (out[0] == 0.0).all() and (out[2] == 0.0).all()
    assert np.abs(out[1]).max() > 0.0  # live rows unaffected by the guard
    np.testing.assert_allclose(
        out, np.asarray(ragged_decode_ref(q, kc, vc, kv_len)), **TOL32
    )


@pytest.mark.parametrize("s,bk", [
    (48, 256),   # bk > S: clamps to the cache length
    (100, 64),   # S % bk != 0: ragged tail padded up to a whole block
    (1, 256),    # single-position cache
    (96, 32),    # exact multiple (control)
])
def test_decode_attention_block_edges(s, bk):
    b, hq, hkv, d = 2, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = _rand(ks[0], (b, hq, d), jnp.float32)
    kc = _rand(ks[1], (b, s, hkv, d), jnp.float32)
    vc = _rand(ks[2], (b, s, hkv, d), jnp.float32)
    kv_len = jnp.array([s, max(s // 2, 1)], jnp.int32)
    out = decode_attention(q, kc, vc, kv_len, bk=bk)
    ref = decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL32)


# --------------------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 2, 16, 32), (2, 256, 4, 64, 64)])
def test_ssd_scan_matches_ref(shape, dtype):
    b, t, h, n, p = shape
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = _rand(ks[0], (b, t, h, n), dtype)
    k = _rand(ks[1], (b, t, h, n), dtype)
    v = _rand(ks[2], (b, t, h, p), dtype)
    w = -jnp.exp(jax.random.normal(ks[3], (b, t, h), jnp.float32)) * 0.3
    out, fin = ssd_scan(q, k, v, w, chunk=64)
    ref_out, ref_fin = ssd_scan_ref(q, k, v, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref_out, np.float32), **TOL
    )
    np.testing.assert_allclose(np.asarray(fin), np.asarray(ref_fin), rtol=2e-2, atol=2e-2)


def test_ssd_scan_matches_naive_sequential():
    """Kernel vs the O(T) per-token recurrence (ground truth)."""
    b, t, h, n, p = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q, k = _rand(ks[0], (b, t, h, n), jnp.float32), _rand(ks[1], (b, t, h, n), jnp.float32)
    v = _rand(ks[2], (b, t, h, p), jnp.float32)
    w = -jnp.exp(jax.random.normal(ks[3], (b, t, h), jnp.float32)) * 0.5
    out, fin = ssd_scan(q, k, v, w, chunk=16)
    ref_out, ref_fin = naive_linear_recurrence(q, k, v, w, include_current=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(ref_fin), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- rwkv6 wkv
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 64, 2, 32), (2, 128, 4, 64)])
def test_wkv6_matches_ref(shape, dtype):
    b, t, h, kd = shape
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    r = _rand(ks[0], (b, t, h, kd), dtype)
    k = _rand(ks[1], (b, t, h, kd), dtype)
    v = _rand(ks[2], (b, t, h, kd), dtype)
    w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (b, t, h, kd)) * 0.5), 1e-4, 0.9)
    u = 0.2 * jax.random.normal(ks[4], (h, kd), jnp.float32)
    out, fin = wkv6(r, k, v, w, u, chunk=32)
    ref_out, ref_fin = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref_out, np.float32), **TOL
    )
    np.testing.assert_allclose(np.asarray(fin), np.asarray(ref_fin), rtol=2e-2, atol=2e-2)


def test_wkv6_matches_naive_sequential():
    b, t, h, kd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    r, k, v = (_rand(kk, (b, t, h, kd), jnp.float32) for kk in ks[:3])
    w = -jnp.clip(jnp.exp(jax.random.normal(ks[3], (b, t, h, kd)) * 0.5), 1e-4, 0.9)
    u = 0.2 * jax.random.normal(ks[4], (h, kd), jnp.float32)
    out, fin = wkv6(r, k, v, w, u, chunk=8)
    ref_out, ref_fin = naive_linear_recurrence(r, k, v, w, include_current=False, bonus=u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(ref_fin), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 64), (3, 7, 128), (1000, 256)])
def test_rmsnorm_matches_ref(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(8))
    x = _rand(k1, shape, dtype)
    w = 1.0 + 0.1 * jax.random.normal(k2, (shape[-1],), jnp.float32)
    out = rmsnorm(x, w)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=1e-2, atol=1e-3
    )


# --------------------------------------------------------------------------- properties
@settings(max_examples=10, deadline=None)
@given(
    sq=st.sampled_from([128, 256]),
    hq=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
)
def test_flash_attention_property(sq, hq, group, d):
    assert hq % group == 0
    hkv = hq // group
    ks = jax.random.split(jax.random.PRNGKey(hq * 131 + d), 3)
    q = _rand(ks[0], (1, sq, hq, d), jnp.float32)
    k = _rand(ks[1], (1, sq, hkv, d), jnp.float32)
    v = _rand(ks[2], (1, sq, hkv, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([32, 64, 96]), h=st.sampled_from([1, 3]), n=st.sampled_from([8, 16]))
def test_ssd_scan_property(t, h, n):
    ks = jax.random.split(jax.random.PRNGKey(t * 7 + h), 4)
    q = _rand(ks[0], (1, t, h, n), jnp.float32)
    k = _rand(ks[1], (1, t, h, n), jnp.float32)
    v = _rand(ks[2], (1, t, h, n), jnp.float32)
    w = -jnp.exp(jax.random.normal(ks[3], (1, t, h))) * 0.4
    out, _ = ssd_scan(q, k, v, w, chunk=32)
    ref, _ = naive_linear_recurrence(q, k, v, w, include_current=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)
