"""Integration: the serving launcher's continuous-batching step loop."""
import pytest

from repro.launch import serve


def test_serve_scheduler_loop_end_to_end(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "6", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "2",
        "--policy", "energy-fair",
    ])
    out = capsys.readouterr().out
    assert "served 6/6 requests" in out
    assert "energy-fair intervals" in out
    assert "per-request energy SLO accounting" in out
    # every request row is printed with measured energy attributed
    for rid in range(6):
        assert f"\n  {rid:>3} client" in out


def test_serve_budget_rejects_when_exhausted(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "4", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0",
        "--budget-j", "1e-12",  # nothing fits
    ])
    out = capsys.readouterr().out
    assert "served 0/4 requests" in out
    assert "(4 rejected by SLO)" in out


def test_serve_bills_only_real_tokens(capsys):
    # 3 requests on 2 slots: the last interval decodes with one padded slot,
    # so billed tokens < decoded tokens and only real tokens are reported
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "3", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0",
    ])
    out = capsys.readouterr().out
    assert "served 3/3 requests" in out
    assert "(0 rejected by SLO), 12 tokens" in out  # 3 x 4, padding excluded
    assert "slot utilization:" in out
    assert "padded slots excluded" in out


def test_serve_churn_arrivals_mid_decode(capsys):
    # requests trickle in every 2 decode steps, joining the live batch
    # mid-decode; all finish and all their tokens are billed exactly once
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "5", "--gen-len", "6",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "2",
        "--arrive-every", "2", "--steps-per-sync", "3",
    ])
    out = capsys.readouterr().out
    assert "served 5/5 requests" in out
    assert "(0 rejected by SLO), 30 tokens" in out  # 5 x 6, billed exactly once
    for rid in range(5):
        assert f"\n  {rid:>3} client" in out


def test_serve_paged_kv_backend_end_to_end(capsys):
    # paged cache backend on an attention arch: admission allocates pages,
    # retire frees them — every page is back in the pool at exit, and churn
    # over more requests than slots actually reuses freed pages
    serve.main([
        "--arch", "qwen25-3b", "--smoke", "--kv", "paged", "--page-size", "8",
        "--requests", "5", "--gen-len", "4", "--prompt-len", "8",
        "--decode-batch", "2", "--fleet", "2", "--arrive-every", "2",
    ])
    out = capsys.readouterr().out
    assert "served 5/5 requests" in out
    assert "(0 rejected by SLO), 20 tokens" in out  # 5 x 4, billed exactly once
    assert "paged KV: page size 8" in out
    assert "0 in use at exit" in out  # retire freed every reservation
    import re

    m = re.search(r"\((\d+) reused", out)
    assert m and int(m.group(1)) > 0, "churn over 2 slots must reuse freed pages"


def test_serve_paged_kv_matches_dense_backend(capsys):
    # same workload, both backends: the billing/throughput accounting and
    # the served set must agree (the decode math is pinned equivalent in
    # test_paged_attention.py)
    args = ["--arch", "qwen25-3b", "--smoke", "--requests", "3", "--gen-len",
            "4", "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0"]
    serve.main(args + ["--kv", "dense"])
    dense_out = capsys.readouterr().out
    serve.main(args + ["--kv", "paged", "--page-size", "8"])
    paged_out = capsys.readouterr().out
    assert "served 3/3 requests" in dense_out
    assert "served 3/3 requests" in paged_out
    assert "(0 rejected by SLO), 12 tokens" in paged_out


def test_serve_paged_kv_rejected_without_attention():
    # rwkv6 has no attention layers: the paged backend must refuse to start
    with pytest.raises(SystemExit):
        serve.main(["--arch", "rwkv6-3b", "--smoke", "--kv", "paged"])
