"""Integration: the serving launcher's continuous-batching step loop."""
import pytest

from repro.launch import serve


def test_serve_scheduler_loop_end_to_end(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "6", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "2",
        "--policy", "energy-fair",
    ])
    out = capsys.readouterr().out
    assert "served 6/6 requests" in out
    assert "energy-fair intervals" in out
    assert "per-request energy SLO accounting" in out
    # every request row is printed with measured energy attributed
    for rid in range(6):
        assert f"\n  {rid:>3} client" in out


def test_serve_budget_rejects_when_exhausted(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "4", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0",
        "--budget-j", "1e-12",  # nothing fits
    ])
    out = capsys.readouterr().out
    assert "served 0/4 requests" in out
    assert "(4 rejected by SLO)" in out


def test_serve_bills_only_real_tokens(capsys):
    # 3 requests on 2 slots: the last interval decodes with one padded slot,
    # so billed tokens < decoded tokens and only real tokens are reported
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "3", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0",
    ])
    out = capsys.readouterr().out
    assert "served 3/3 requests" in out
    assert "(0 rejected by SLO), 12 tokens" in out  # 3 x 4, padding excluded
    assert "slot utilization:" in out
    assert "padded slots excluded" in out


def test_serve_churn_arrivals_mid_decode(capsys):
    # requests trickle in every 2 decode steps, joining the live batch
    # mid-decode; all finish and all their tokens are billed exactly once
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "5", "--gen-len", "6",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "2",
        "--arrive-every", "2", "--steps-per-sync", "3",
    ])
    out = capsys.readouterr().out
    assert "served 5/5 requests" in out
    assert "(0 rejected by SLO), 30 tokens" in out  # 5 x 6, billed exactly once
    for rid in range(5):
        assert f"\n  {rid:>3} client" in out
