"""Integration: the serving launcher's scheduler-driven wave loop."""
import pytest

from repro.launch import serve


def test_serve_scheduler_loop_end_to_end(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "6", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "2",
        "--policy", "energy-fair",
    ])
    out = capsys.readouterr().out
    assert "served 6/6 requests" in out
    assert "energy-fair waves" in out
    assert "per-request energy SLO accounting" in out
    # every request row is printed with measured energy attributed
    for rid in range(6):
        assert f"\n  {rid:>3} client" in out


def test_serve_budget_rejects_when_exhausted(capsys):
    serve.main([
        "--arch", "rwkv6-3b", "--smoke", "--requests", "4", "--gen-len", "4",
        "--prompt-len", "8", "--decode-batch", "2", "--fleet", "0",
        "--budget-j", "1e-12",  # nothing fits
    ])
    out = capsys.readouterr().out
    assert "served 0/4 requests" in out
    assert "(4 rejected by SLO)" in out
