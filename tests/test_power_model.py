"""TPU chip model: power arithmetic, roofline terms, DVFS, phases."""
import numpy as np
import pytest

from repro.power import (
    V5E,
    DvfsState,
    Phase,
    StepCost,
    phases_for_step,
    render_phases,
    step_duration,
    step_energy,
)


def test_idle_power_is_static_floor():
    assert V5E.power() == V5E.p_static


def test_power_monotone_in_rates():
    p0 = V5E.power(flop_rate=0.0)
    p1 = V5E.power(flop_rate=V5E.peak_flops_bf16)
    p2 = V5E.power(flop_rate=V5E.peak_flops_bf16, hbm_rate=V5E.hbm_bw)
    assert p0 < p1 < p2
    assert 150 < p2 < 300  # sane busy-chip wattage


def test_roofline_terms():
    tc, tm, tn = V5E.roofline_times(197e12, 819e9, V5E.ici_bw)
    assert tc == pytest.approx(1.0)
    assert tm == pytest.approx(1.0)
    assert tn == pytest.approx(1.0)


def test_dvfs_power_factor_monotone():
    states = DvfsState.sweep(0.6, 1.0, 5)
    factors = [s.power_factor for s in states]
    assert factors == sorted(factors)
    assert states[-1].power_factor == pytest.approx(1.0)


def test_dvfs_energy_tradeoff():
    """Lower clock: compute-bound step is slower but cheaper in J."""
    cost = StepCost(flops=1e12, hbm_bytes=1e9, ici_bytes=0.0)
    full = phases_for_step(cost, n_layers=4, dvfs=DvfsState(1.0))
    slow = phases_for_step(cost, n_layers=4, dvfs=DvfsState(0.6))
    t_full, t_slow = step_duration(full), step_duration(slow)
    e_full = step_energy(full, dvfs=DvfsState(1.0))
    e_slow = step_energy(slow, dvfs=DvfsState(0.6))
    assert t_slow > t_full
    # dynamic energy shrinks with f*V^2; static grows with time — the
    # tradeoff exists iff dynamic dominates, which it does here
    assert e_slow < e_full


def test_phases_conserve_cost():
    cost = StepCost(flops=5e12, hbm_bytes=2e11, ici_bytes=3e10)
    phases = phases_for_step(cost, n_layers=7)
    assert sum(p.flops for p in phases) == pytest.approx(cost.flops, rel=1e-6)
    assert sum(p.hbm_bytes for p in phases) == pytest.approx(cost.hbm_bytes, rel=1e-6)
    assert sum(p.ici_bytes for p in phases) == pytest.approx(cost.ici_bytes, rel=1e-6)


def test_overlap_shortens_step():
    cost = StepCost(flops=5e12, hbm_bytes=2e11, ici_bytes=3e11)
    t_seq = step_duration(phases_for_step(cost, 8, overlap_collectives=False))
    t_ovl = step_duration(phases_for_step(cost, 8, overlap_collectives=True))
    assert t_ovl < t_seq


def test_render_energy_matches_phase_sum():
    cost = StepCost(flops=1e12, hbm_bytes=1e11, ici_bytes=1e10)
    phases = phases_for_step(cost, n_layers=3)
    tr = render_phases(phases, V5E)
    assert tr.energy_j == pytest.approx(step_energy(phases, V5E), rel=0.02)


def test_render_repeat_and_idle():
    phases = [Phase("k", 0.001, flops=1e9)]
    tr = render_phases(phases, V5E, idle_before_s=0.01, idle_after_s=0.01, repeat=5)
    assert tr.duration_s == pytest.approx(0.01 * 2 + 0.005, rel=1e-6)
    assert len(tr.phase_marks) == 5
