"""Energy-aware autotuner: Pareto front + the 3.25× methodology claim."""
import numpy as np
import pytest

from repro.power import (
    DvfsState,
    KernelVariantModel,
    StepCost,
    V5E,
    EnergyTuner,
    builtin_counter_strategy,
    fast_sensor_strategy,
    tuning_speedup,
)


def _toy_kernel() -> KernelVariantModel:
    """Synthetic kernel: block=128 is MXU-aligned (fast); smaller blocks
    lose efficiency. ~1 ms class, like the paper's beamformer variants."""
    flops = 2 * 4096**3  # complex-GEMM-sized

    def model(cfg, chip, dvfs):
        align = 1.0 if cfg["block"] % 128 == 0 else 0.55
        eff = align * (0.95 if cfg["double_buffer"] else 0.75)
        t = flops / (chip.peak_flops_bf16 * eff * dvfs.scale)
        bytes_ = 3 * 4096**2 * 2 * (128 / cfg["block"])
        return t, StepCost(flops=flops, hbm_bytes=bytes_, ici_bytes=0.0)

    return KernelVariantModel(
        name="toy-gemm",
        useful_flops=flops,
        model=model,
        search_space={"block": (64, 128, 256), "double_buffer": (False, True)},
    )


def test_search_space_enumeration():
    k = _toy_kernel()
    cfgs = list(k.configs())
    assert len(cfgs) == 6
    assert {"block", "double_buffer"} == set(cfgs[0])


def test_tuner_finds_aligned_config_fastest():
    res = EnergyTuner().tune(_toy_kernel(), fast_sensor_strategy(), exact_energy=True)
    best = res.fastest()
    assert best.config["block"] % 128 == 0
    assert best.config["double_buffer"] is True


def test_dvfs_expands_pareto_front():
    states = DvfsState.sweep(0.6, 1.0, 5)
    res = EnergyTuner().tune(
        _toy_kernel(), fast_sensor_strategy(), dvfs_states=states, exact_energy=True
    )
    front = res.pareto_front()
    assert len(front) >= 2  # speed/efficiency tradeoff exists
    fastest, efficient = res.fastest(), res.most_efficient()
    assert efficient.tflop_per_j > fastest.tflop_per_j
    assert fastest.tflops > efficient.tflops
    # paper Fig 8: most-efficient config trades some speed for efficiency
    assert efficient.dvfs_scale < fastest.dvfs_scale


def test_pareto_front_is_nondominated():
    states = DvfsState.sweep(0.6, 1.0, 5)
    res = EnergyTuner().tune(
        _toy_kernel(), fast_sensor_strategy(), dvfs_states=states, exact_energy=True
    )
    front = res.pareto_front()
    for f in front:
        dominated = any(
            (o.tflops >= f.tflops and o.tflop_per_j > f.tflop_per_j)
            or (o.tflops > f.tflops and o.tflop_per_j >= f.tflop_per_j)
            for o in res.records
        )
        assert not dominated


def test_tuning_speedup_vs_builtin_counter():
    """Fast sensor ≫ faster tuning; paper reports 3.25× on ms-class kernels."""
    speedup, fast, slow = tuning_speedup(_toy_kernel(), dvfs_states=DvfsState.sweep(n=3))
    assert speedup > 2.0
    assert fast.total_tuning_time_s < slow.total_tuning_time_s
    # same winners regardless of meter (energies agree; only cost differs)
    assert fast.fastest().config == slow.fastest().config


def test_measured_energy_close_to_model():
    """Virtual-sensor-measured joules track the model integral."""
    k = _toy_kernel()
    tuner = EnergyTuner()
    exact = tuner.tune(k, fast_sensor_strategy(), exact_energy=True)
    measured = tuner.tune(k, fast_sensor_strategy(), exact_energy=False)
    for e, m in zip(exact.records, measured.records):
        # sensor sees idle floor padding too; allow modest tolerance
        assert m.joules == pytest.approx(e.joules, rel=0.25)
