"""Test-suite bootstrap: make `hypothesis` an *optional* dependency.

The property-based tests (`test_protocol.py`, `test_optim.py`,
`test_kernels.py`) import `hypothesis` at module scope, which used to kill
the whole tier-1 run at collection time on machines without it.  If the
real package is installed (``pip install -r requirements-dev.txt``) this
file does nothing and the full property-based suite runs.  Otherwise a
minimal deterministic shim is installed into ``sys.modules``: ``@given``
re-runs the test body a bounded number of times with values drawn from a
seeded RNG, so the properties are still exercised (smoke-level) instead of
being skipped wholesale.
"""
from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

try:  # pragma: no cover - trivial branch
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if not HAVE_HYPOTHESIS:
    import numpy as np

    _MAX_EXAMPLES_CAP = 25  # keep the shimmed property runs cheap

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    def tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))

    def lists(elements, min_size=0, max_size=10):
        def _draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]

        return _Strategy(_draw)

    def given(*arg_strategies, **kw_strategies):
        def decorator(fn):
            @functools.wraps(fn)
            def wrapper():
                n = min(getattr(wrapper, "_shim_max_examples", 10), _MAX_EXAMPLES_CAP)
                rng = np.random.default_rng(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    args = tuple(s.draw(rng) for s in arg_strategies)
                    kwargs = {name: s.draw(rng) for name, s in kw_strategies.items()}
                    fn(*args, **kwargs)

            # pytest must not mistake the wrapped function's parameters for
            # fixtures: present a zero-argument signature.
            wrapper.__signature__ = inspect.Signature(parameters=[])
            if hasattr(wrapper, "__wrapped__"):
                del wrapper.__wrapped__
            # honor a @settings applied beneath @given (wraps copied it here)
            wrapper._shim_max_examples = getattr(fn, "_shim_max_examples", 10)
            return wrapper

        return decorator

    def settings(max_examples=None, deadline=None, **_kw):
        def decorator(fn):
            if max_examples is not None:
                fn._shim_max_examples = min(int(max_examples), _MAX_EXAMPLES_CAP)
            return fn

        return decorator

    _mod = types.ModuleType("hypothesis")
    _mod.given = given
    _mod.settings = settings
    _st = types.ModuleType("hypothesis.strategies")
    for _f in (integers, booleans, floats, sampled_from, tuples, lists):
        setattr(_st, _f.__name__, _f)
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
