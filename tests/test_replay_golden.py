"""Golden-corpus regression tier: committed archives vs their manifests.

Replays each committed golden archive through the real host receiver and
asserts every sensor-derived metric against the committed tolerance
manifest — any drift in the receiver, ring, attribution, fleet
aggregation or replay transport shows up here as a manifest violation.
(`tools/regen_goldens.py --check` additionally re-records the scenarios
live in CI, catching staleness in the other direction.)
"""
import json
from pathlib import Path

import pytest

from repro.replay import TraceArchive
from repro.replay.golden import (
    MAX_CORPUS_BYTES,
    SCENARIOS,
    _compare,
    archive_since,
    check_goldens,
    corpus_bytes,
    load_manifest,
    replay_session_metrics,
)

GOLDEN_DIR = Path(__file__).parent / "goldens"


@pytest.fixture(scope="module")
def manifest():
    return load_manifest(GOLDEN_DIR)


def test_corpus_is_committed_and_mini(manifest):
    assert set(manifest["scenarios"]) == set(SCENARIOS)
    total = corpus_bytes(GOLDEN_DIR)
    assert 0 < total <= MAX_CORPUS_BYTES, f"corpus is {total} bytes"


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_golden_scenario_replays_to_manifest(name, manifest):
    entry = manifest["scenarios"][name]
    archive = TraceArchive.load(GOLDEN_DIR / entry["archive"])
    # golden archives must be clean recordings: nothing lossy, nothing lost
    for tr in archive.devices.values():
        assert tr.n_quantised == 0
        assert tr.n_time_quantised == 0
        assert tr.lost_frames == 0
    metrics = replay_session_metrics(SCENARIOS[name], archive)
    errors = _compare(name, metrics, entry, skip_live=True)
    assert not errors, "\n".join(errors)


def test_chaos_goldens_carry_their_fault_ledgers():
    for name in ("chaos-dropout", "chaos-disconnect"):
        archive = TraceArchive.load(
            GOLDEN_DIR / load_manifest(GOLDEN_DIR)["scenarios"][name]["archive"]
        )
        assert any(
            tr.fault_ledger is not None and tr.fault_ledger.dropped_s > 0
            for tr in archive.devices.values()
        ), f"{name}: no injected gaps in any device ledger"


def test_golden_roundtrip_one_scenario_rerecorded():
    """One cheap live re-record in-tier: the round-trip invariant holds
    against the *committed* manifest, not just at regen time."""
    errors = check_goldens(GOLDEN_DIR, names=["serve-wave"], rerecord=False)
    assert not errors, "\n".join(errors)
    archive, live = SCENARIOS["serve-wave"].record()
    replayed = replay_session_metrics(SCENARIOS["serve-wave"], archive)
    manifest = load_manifest(GOLDEN_DIR)
    entry = manifest["scenarios"]["serve-wave"]
    for key, spec in entry["metrics"].items():
        assert key in replayed
        assert abs(replayed[key] - spec["value"]) <= (
            spec["atol"] + spec["rtol"] * abs(spec["value"])
        ), key
        assert abs(replayed[key] - live[key]) <= 1e-9 * max(abs(live[key]), 1e-12)


def test_manifest_tolerances_are_tight():
    """Sensor metrics are pinned at the 1e-9 round-trip contract, not at
    hand-wavy tolerances that would let regressions hide."""
    manifest = load_manifest(GOLDEN_DIR)
    for name, entry in manifest["scenarios"].items():
        for key, spec in entry["metrics"].items():
            if key.startswith("live."):
                continue
            assert spec["rtol"] <= 1e-9, (name, key)
            assert spec["atol"] <= 1e-12, (name, key)


def test_stale_manifest_is_detected(tmp_path):
    """check_goldens flags a manifest whose pinned values drifted."""
    import shutil

    work = tmp_path / "goldens"
    shutil.copytree(GOLDEN_DIR, work)
    manifest = json.loads((work / "manifest.json").read_text())
    entry = manifest["scenarios"]["serve-wave"]["metrics"]["dev0.energy_j"]
    entry["value"] *= 1.01  # 1% drift, far outside 1e-9
    (work / "manifest.json").write_text(json.dumps(manifest))
    errors = check_goldens(work, rerecord=False)
    assert any("dev0.energy_j" in e for e in errors)


def test_archive_since_covers_all_devices():
    manifest = load_manifest(GOLDEN_DIR)
    entry = manifest["scenarios"]["governor-step"]
    archive = TraceArchive.load(GOLDEN_DIR / entry["archive"])
    since = archive_since(archive)
    assert set(since) == set(archive.devices)
    assert all(t > 0 for t in since.values())


# ----------------------------------------------------- regeneration paths
def test_write_goldens_regenerates_a_fresh_corpus(tmp_path):
    """`write_goldens` = what `tools/regen_goldens.py` runs: every
    scenario records, round-trips within 1e-9, and lands under budget."""
    from repro.replay.golden import write_goldens

    out = tmp_path / "fresh"
    manifest = write_goldens(out)
    assert set(manifest["scenarios"]) == set(SCENARIOS)
    assert 0 < corpus_bytes(out) <= MAX_CORPUS_BYTES
    # the freshly written corpus verifies against itself, live re-record
    # included (this is the --check CI gate, end to end)
    assert check_goldens(out, rerecord=True) == []
    # and matches the committed manifest: regeneration is deterministic
    committed = load_manifest(GOLDEN_DIR)
    fresh = load_manifest(out)
    for name, entry in committed["scenarios"].items():
        for key, spec in entry["metrics"].items():
            got = fresh["scenarios"][name]["metrics"][key]["value"]
            assert abs(got - spec["value"]) <= (
                spec["atol"] + spec["rtol"] * abs(spec["value"])
            ), (name, key)


def test_golden_error_paths(tmp_path):
    from repro.replay.golden import GoldenError

    with pytest.raises(GoldenError, match="no golden manifest"):
        load_manifest(tmp_path)
    (tmp_path / "manifest.json").write_text(json.dumps({"version": 99}))
    with pytest.raises(GoldenError, match="version"):
        load_manifest(tmp_path)
    # a manifest naming an unknown scenario / a missing archive → violations
    (tmp_path / "manifest.json").write_text(
        json.dumps(
            {
                "version": 1,
                "scenarios": {
                    "no-such-scenario": {"archive": "x.npz", "metrics": {}},
                    "serve-wave": {"archive": "missing.npz", "metrics": {}},
                },
            }
        )
    )
    errors = check_goldens(tmp_path, rerecord=False)
    assert any("unknown scenario" in e for e in errors)
    assert any("missing golden archive" in e for e in errors)
    assert any("not in the committed manifest" in e for e in errors)


def test_unpinned_metric_is_a_violation(tmp_path):
    """A session producing metrics the manifest doesn't pin fails the
    check — silent coverage shrinkage of the pinned set is not allowed."""
    import shutil

    work = tmp_path / "goldens"
    shutil.copytree(GOLDEN_DIR, work)
    manifest = json.loads((work / "manifest.json").read_text())
    del manifest["scenarios"]["serve-wave"]["metrics"]["dev0.energy_j"]
    (work / "manifest.json").write_text(json.dumps(manifest))
    errors = check_goldens(work, rerecord=False)
    assert any("unpinned metric" in e and "dev0.energy_j" in e for e in errors)


def test_partial_regen_preserves_other_scenarios(tmp_path):
    """`regen_goldens.py --scenario X` must merge into the committed
    manifest, not drop every other scenario's pins."""
    import shutil

    from repro.replay.golden import write_goldens

    work = tmp_path / "goldens"
    shutil.copytree(GOLDEN_DIR, work)
    write_goldens(work, names=["chaos-dropout"])
    manifest = load_manifest(work)
    assert set(manifest["scenarios"]) == set(SCENARIOS)
    assert check_goldens(work, rerecord=False) == []


def test_wave_goldens_attribute_bit_identically_through_intervals():
    """Wave-marker goldens through the refactored attribution path.

    Wave markers are the degenerate one-interval-per-wave case of step
    -interval attribution: `attribute_intervals` keyed by global interval
    index must reproduce the legacy `attribute_block(marker_spans(...))`
    ledger **bit-for-bit** (`==`, not approx) on every committed golden
    that carries markers — clean serving and chaos recordings alike.
    """
    from repro.attrib import attribute_block, attribute_intervals, marker_spans
    from repro.replay import ReplayFleet

    manifest = load_manifest(GOLDEN_DIR)
    checked = 0
    for name, scenario in sorted(SCENARIOS.items()):
        char = scenario.wave_char
        if char is None:
            continue
        entry = manifest["scenarios"][name]
        archive = TraceArchive.load(GOLDEN_DIR / entry["archive"])
        fleet = ReplayFleet(archive, window_s=scenario.window_s)
        try:
            fleet.drain()
            for dev in fleet.monitor.names:
                ps = fleet.monitor[dev]
                block = ps.ring.latest()
                legacy = attribute_block(block, marker_spans(ps.markers, char))
                stepped = attribute_intervals(block, ps.markers, char)
                assert {
                    int(n[len(char):]): e for n, e in legacy.entries.items()
                } == stepped, (name, dev)
                if legacy.entries:
                    checked += 1
        finally:
            fleet.close()
    assert checked > 0  # the parity claim was exercised, not vacuous
