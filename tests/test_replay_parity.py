"""Replay-parity tier: record → archive → replay ≡ the live run.

The subsystem's contract: a recorded session replayed through
`ReplayDevice` (i.e. through the *real* host receiver) must reproduce
`attribute()` ledger joules and `FleetMonitor.window_power_w` within
1e-9 relative of the live run — for clean sessions *and* for chaos runs
whose `FaultLedger` gaps punch holes in the stream.
"""
import numpy as np
import pytest

from repro.attrib import KernelSpan, attribute_block, marker_spans
from repro.core import ConstantLoad, SquareWaveLoad
from repro.faultlab import inject, shipped_scenarios
from repro.replay import ReplayFleet, SessionRecorder, load_bytes, save_bytes
from repro.stream import make_virtual_fleet

RTOL = 1e-9


def _rel(a: float, b: float) -> float:
    return abs(a - b) / max(abs(b), 1e-300)


def _wave_ledgers(monitor, char: str):
    """Per-device whole-span + per-wave attribution from the rings."""
    out = {}
    for name in monitor.names:
        ps = monitor[name]
        block = ps.ring.latest()
        spans = [KernelSpan("all", block.times_s[0], block.times_s[-1])]
        spans += marker_spans(ps.markers, char)
        out[name] = attribute_block(block, spans)
    return out


def _record_clean_session():
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 3.0), SquareWaveLoad(12.0, 2.0, 6.0, freq_hz=90.0)],
        window_s=0.05,
        seed=13,
        ring_capacity=1 << 13,
    )
    rec = SessionRecorder(fleet)
    for _ in range(4):
        fleet.mark_all("W")
        fleet.run_for(0.03, chunk_s=0.005)
        rec.capture()
    fleet.mark_all("W")
    fleet.run_for(0.005, chunk_s=0.005)
    return fleet, rec.finalize()


def _record_chaos_session(scenario_name: str):
    scen = shipped_scenarios(0.3)[scenario_name]
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 3.0), ConstantLoad(12.0, 4.0)],
        window_s=0.02,
        seed=23,
        ring_capacity=1 << 14,
    )
    transports = inject(fleet, scen)
    rec = SessionRecorder(fleet)
    t, next_mark = 0.0, 0.0
    while t < 0.3 - 1e-12:
        if t >= next_mark - 1e-12:
            fleet.mark_all("C")
            next_mark += 0.05
        fleet.advance(0.002)
        t += 0.002
        rec.capture()
    fleet.poll_all()
    return fleet, transports, rec.finalize()


def test_clean_session_replay_parity():
    fleet, archive = _record_clean_session()
    live = _wave_ledgers(fleet, "W")
    live_power = fleet.window_power_w(0.05, poll=False)

    replay = ReplayFleet(load_bytes(save_bytes(archive)))
    replay.drain()
    replayed = _wave_ledgers(replay.monitor, "W")
    replay_power = replay.monitor.window_power_w(0.05, poll=False)

    assert _rel(replay_power, live_power) <= RTOL
    for name in fleet.names:
        llive, lrep = live[name], replayed[name]
        assert set(lrep.entries) == set(llive.entries)
        assert len(llive.entries) == 5  # whole span + 4 waves
        for key, ent in llive.entries.items():
            rent = lrep.entries[key]
            assert _rel(rent.energy_j, ent.energy_j) <= RTOL, key
            assert rent.count == ent.count
            assert _rel(rent.peak_w, ent.peak_w) <= RTOL
        assert _rel(lrep.trace_energy_j, llive.trace_energy_j) <= RTOL
    replay.close()
    fleet.close()


@pytest.mark.parametrize("scenario", ["dropout-burst", "disconnect-cycle"])
def test_chaos_session_replay_parity(scenario):
    fleet, transports, archive = _record_chaos_session(scenario)
    live = _wave_ledgers(fleet, "C")
    live_power = fleet.window_power_w(0.02, poll=False)

    loaded = load_bytes(save_bytes(archive))
    replay = ReplayFleet(loaded)
    replay.drain()
    replayed = _wave_ledgers(replay.monitor, "C")
    replay_power = replay.monitor.window_power_w(0.02, poll=False)

    assert _rel(replay_power, live_power) <= RTOL
    saw_gap = False
    for name in fleet.names:
        llive, lrep = live[name], replayed[name]
        for key, ent in llive.entries.items():
            rent = lrep.entries[key]
            assert _rel(rent.energy_j, ent.energy_j) <= RTOL, (scenario, key)
            # coverage (the gap accounting) must survive the round trip too
            assert _rel(rent.covered_s, ent.covered_s) <= RTOL
            saw_gap |= ent.coverage_frac < 0.999
        # the injected ground truth rides in the archive
        led = loaded.devices[name].fault_ledger
        assert led is not None
        src = transports[name].ledger
        assert led.delivered_frac == src.delivered_frac
        assert led.gap_spans() == src.gap_spans()
    assert saw_gap  # the scenario really did punch holes the ledger attributes
    replay.close()
    fleet.close()


def test_chaos_replay_frames_bit_identical():
    """Stronger than the 1e-9 contract: the decoded frames themselves."""
    fleet, _, archive = _record_chaos_session("dropout-burst")
    replay = ReplayFleet(load_bytes(save_bytes(archive)))
    replay.drain()
    for name in fleet.names:
        tr = archive.devices[name]
        live = fleet[name].ring.latest()
        rep = replay[name].ring.latest()
        k = len(tr)
        np.testing.assert_array_equal(rep.times_s, live.times_s[-k:])
        np.testing.assert_array_equal(rep.volts, live.volts[-k:])
        np.testing.assert_array_equal(rep.amps, live.amps[-k:])
        np.testing.assert_array_equal(rep.watts, live.watts[-k:])
        assert replay[name].markers == [
            m for m in fleet[name].markers if m[1] >= live.times_s[-k]
        ]
    replay.close()
    fleet.close()


def test_realtime_replay_matches_max_speed():
    """Wall-clock-paced replay lands on the same frames as max speed."""
    fleet, _, archive = _record_chaos_session("disconnect-cycle")
    fleet.close()
    fast = ReplayFleet(archive)
    fast.drain()
    paced = ReplayFleet(archive, realtime=True)
    for _ in range(400):
        paced.advance(0.001)
    for name in fast.names:
        a = fast[name].ring.latest()
        b = paced[name].ring.latest()
        np.testing.assert_array_equal(a.times_s, b.times_s)
        np.testing.assert_array_equal(a.watts, b.watts)
        assert fast[name].markers == paced[name].markers
    fast.close()
    paced.close()


def test_serve_launcher_record_flag(tmp_path):
    """`--record` on the serving launcher writes a replayable archive."""
    from repro.launch import serve
    from repro.replay import ReplayFleet, TraceArchive

    path = tmp_path / "serve.npz"
    serve.main(
        [
            "--arch", "qwen1.5-4b", "--smoke",
            "--requests", "4", "--decode-batch", "2",
            "--prompt-len", "8", "--gen-len", "4",
            "--fleet", "2", "--record", str(path),
        ]
    )
    archive = TraceArchive.load(path)
    assert len(archive) == 2
    assert archive.n_frames > 0
    assert archive.meta["launcher"] == "serve"
    assert archive.meta["intervals"] >= 1
    # at least one wave bracket per device made it into the archive
    assert all(tr.marker_chars for tr in archive.devices.values())
    replay = ReplayFleet(archive)
    assert replay.drain() == archive.n_frames
    assert replay.monitor.window_power_w(0.5, poll=False) > 0
    replay.close()


def test_train_recording_attributor(tmp_path):
    """The train launcher's recording attributor archives its session."""
    from repro.launch.train import make_recording_attributor
    from repro.power import EnergyTelemetry, StepCost
    from repro.replay import TraceArchive, replay_sensor

    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2e9, 1e9, 0.0), n_layers=2,
        useful_flops_per_step=2e9,
    )
    path = tmp_path / "train.npz"
    attributor = make_recording_attributor(str(path), telemetry, seed=3)
    for _ in range(3):
        attributor.on_step()
    ledger = attributor.finish()
    archive = TraceArchive.load(path)
    trace = archive.devices["train"]
    assert len(trace) > 0
    assert trace.marker_chars.count("S") == 3
    ps = replay_sensor(trace)
    while not ps.device.exhausted:
        ps.poll()
    # re-attribute the replayed session: same marker anchors, same energy
    block = ps.ring.latest()
    spans = marker_spans(ps.markers, "S")
    replayed = attribute_block(block, spans)
    live_total = sum(e.energy_j for e in ledger.entries.values())
    assert replayed.trace_energy_j > 0
    assert live_total > 0
    ps.close()
