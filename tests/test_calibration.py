"""Calibration procedure (§III-D): offset/gain recovery within Table I."""
import numpy as np
import pytest

from repro.core import ConstantLoad, Joules, PowerSensor, SweepLoad, Watt, make_device, seconds
from repro.core.calibration import calibrate
from repro.core.sensors import MODULE_CATALOG


def _calibrated_sensor(module="slot-10a-12v", vrail=12.0, seed=42, n=8000):
    dev = make_device([module], ConstantLoad(vrail, 0.0), seed=seed)
    ps = PowerSensor(dev)
    reports = calibrate(ps, {0: vrail}, n_samples=n)
    return ps, reports


def test_calibration_recovers_offset():
    ps, reports = _calibrated_sensor(seed=21)
    fw = ps.device.firmware
    true_off = fw.modules[0].hall_offset_amps
    assert reports[0].current_offset_amps == pytest.approx(true_off, abs=0.01)


def test_calibration_recovers_gain():
    ps, reports = _calibrated_sensor(seed=22)
    fw = ps.device.firmware
    true_gain_err = fw.modules[0].divider_gain_error
    # measured gain correction should invert the manufacturing gain error
    assert reports[0].voltage_gain == pytest.approx(1.0 / (1.0 + true_gain_err), rel=2e-3)


@pytest.mark.parametrize("seed", [1, 2, 3])
def test_post_calibration_accuracy_within_table1(seed):
    """After calibration, measured power is within Table I worst case."""
    module = "slot-10a-12v"
    vrail, amps = 12.0, 8.0
    dev = make_device([module], ConstantLoad(vrail, 0.0), seed=seed)
    ps = PowerSensor(dev)
    calibrate(ps, {0: vrail}, n_samples=8000)
    # switch the same (calibrated) device to a loaded DUT
    dev.firmware.dut.loads[0] = ConstantLoad(vrail, amps)
    a = ps.read()
    ps.run_for(0.5)
    b = ps.read()
    spec = MODULE_CATALOG[module]
    measured = Watt(a, b)
    # mean of 10k samples ≈ true power well within worst-case single-sample
    assert measured == pytest.approx(vrail * amps, abs=spec.power_error / 3)


def test_calibration_only_needed_once():
    """§IV-B: re-measuring later (no recalibration) stays accurate."""
    ps, _ = _calibrated_sensor(seed=23)
    dev = ps.device
    dev.firmware.dut.loads[0] = ConstantLoad(12.0, 7.5)
    drift = []
    for _ in range(5):
        a = ps.read()
        ps.run_for(0.2)
        b = ps.read()
        drift.append(Watt(a, b))
    assert np.ptp(drift) < 0.5  # paper: ±0.09 W mean fluctuation over 50 h


def test_sweep_error_profile_fig4():
    """Fig 4: error vs load current stays inside worst-case bounds."""
    module = "slot-10a-12v"
    steps = np.arange(-10, 11, 2.0)
    dev = make_device([module], ConstantLoad(12.0, 0.0), seed=24)
    ps = PowerSensor(dev)
    calibrate(ps, {0: 12.0}, n_samples=8000)
    spec = MODULE_CATALOG[module]
    for amps in steps:
        dev.firmware.dut.loads[0] = ConstantLoad(12.0, float(amps))
        a = ps.read()
        ps.run_for(0.1)
        b = ps.read()
        err = Watt(a, b) - 12.0 * amps
        assert abs(err) < spec.power_error
