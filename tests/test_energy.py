"""Energy telemetry: records, summary, sensor cross-check."""
import io

import numpy as np
import pytest

from repro.power import EnergyTelemetry, StepCost


def _tel():
    return EnergyTelemetry(
        cost_per_step=StepCost(flops=2e12, hbm_bytes=5e11, ici_bytes=3e10),
        n_layers=8,
        useful_flops_per_step=1.8e12,
    )


def test_modelled_step_consistency():
    t = _tel()
    # energy = avg power * time, power within chip envelope
    p = t.modelled_step_joules / t.modelled_step_time_s
    assert t.chip.p_static < p < t.chip.p_peak + 50


def test_records_and_summary():
    t = _tel()
    for i in range(4):
        t.record_step(i, wall_time_s=0.1, tokens=1000)
    s = t.summary()
    assert s["steps"] == 4
    assert s["total_joules"] == pytest.approx(4 * t.modelled_step_joules)
    assert s["j_per_token"] == pytest.approx(t.modelled_step_joules / 1000)
    assert s["tflop_per_j"] > 0


def test_csv_output():
    t = _tel()
    t.record_step(0, 0.1, 10)
    buf = io.StringIO()
    t.write_csv(buf)
    lines = buf.getvalue().splitlines()
    assert lines[0].startswith("step,")
    assert len(lines) == 2


def test_sensor_cross_check_agrees():
    t = _tel()
    res = t.verify_with_sensor(seed=1)
    assert abs(res["rel_err"]) < 0.05


def test_overlap_reduces_step_time_not_energy_much():
    base = _tel()
    ovl = EnergyTelemetry(
        cost_per_step=StepCost(2e12, 5e11, 3e10), n_layers=8,
        useful_flops_per_step=1.8e12, overlap_collectives=True,
    )
    assert ovl.modelled_step_time_s < base.modelled_step_time_s
    # same work: dynamic energy equal; only static floor time shrinks
    assert ovl.modelled_step_joules < base.modelled_step_joules
