"""Paged KV pool + paged decode-attention kernel.

Both decode kernels — the dense-slab `decode_attention` and the paged
one — are checked against the SAME ragged oracle (`ragged_decode_ref`),
so the ``kv_len == 0 -> exact zeros`` contract is pinned down once and
enforced twice.  The pool tests churn alloc/free/defrag and assert the
allocator invariants the serve loop depends on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import decode_attention
from repro.kernels.paged_attention import (
    NULL_PAGE,
    PagedKVPool,
    apply_page_permutation,
    gather_pages,
    init_page_arrays,
    pack_prefill_pages,
    paged_decode_attention,
    paged_decode_attention_ref,
    paged_tuner_model,
    pages_for,
    ragged_decode_ref,
)

TOL = dict(rtol=2e-2, atol=2e-3)
TOL32 = dict(rtol=1e-3, atol=1e-3)


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _build_paged(rng, kv_lens, ps, max_pages, hkv, d, dtype):
    """Pool + page arrays + dense mirror for a batch of ragged lengths."""
    b = len(kv_lens)
    pool = PagedKVPool(n_pages=1 + b * max_pages, page_size=ps)
    kp, vp = init_page_arrays(pool.n_pages, ps, hkv, d, dtype)
    s = max_pages * ps
    kd = np.zeros((b, s, hkv, d), np.float32)
    vd = np.zeros_like(kd)
    slot_rids = []
    for r, ln in enumerate(kv_lens):
        if ln == 0:
            slot_rids.append(None)
            continue
        pages = pool.alloc(r, ln)
        assert pages is not None
        pool.note_tokens(r, ln)
        k = rng.normal(size=(ln, hkv, d)).astype(np.float32)
        v = rng.normal(size=(ln, hkv, d)).astype(np.float32)
        kd[r, :ln], vd[r, :ln] = k, v
        kp, vp = pack_prefill_pages(
            kp, vp, jnp.asarray(k, dtype), jnp.asarray(v, dtype),
            jnp.asarray(pages, jnp.int32),
        )
        slot_rids.append(r)
    table = jnp.asarray(pool.table(slot_rids, max_pages))
    lens = jnp.asarray(pool.kv_lens(slot_rids))
    return pool, kp, vp, table, lens, jnp.asarray(kd, dtype), jnp.asarray(vd, dtype)


# --------------------------------------------------------------------------- kernel
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [
    # (ps, max_pages, Hq, Hkv, D, kv_lens) — incl. 0, 1, ragged, exactly full
    (16, 4, 4, 4, 64, (0, 1, 37, 64)),
    (32, 2, 8, 2, 64, (0, 33, 64)),    # GQA group 4
    (8, 3, 4, 1, 128, (24, 5)),        # MQA, exact page multiple
])
def test_paged_decode_matches_oracles(shape, dtype):
    ps, max_pages, hq, hkv, d, kv_lens = shape
    rng = np.random.default_rng(sum(kv_lens) + ps)
    _, kp, vp, table, lens, kd, vd = _build_paged(
        rng, kv_lens, ps, max_pages, hkv, d, dtype
    )
    b = len(kv_lens)
    q = _rand(jax.random.PRNGKey(0), (b, hq, d), dtype)
    out = paged_decode_attention(q, kp, vp, table, lens)
    tol = TOL32 if dtype == jnp.float32 else TOL
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(paged_decode_attention_ref(q, kp, vp, table, lens), np.float32),
        **tol,
    )
    # and vs the dense ragged oracle on the mirrored dense cache
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ragged_decode_ref(q, kd, vd, lens), np.float32),
        **tol,
    )


def test_paged_decode_kv0_rows_exact_zero():
    """Free/padded slots (kv_len == 0) must be *exact* zeros, never NaN."""
    rng = np.random.default_rng(0)
    _, kp, vp, table, lens, _, _ = _build_paged(
        rng, (0, 13, 0), 8, 2, 2, 32, jnp.float32
    )
    q = _rand(jax.random.PRNGKey(1), (3, 4, 32), jnp.float32)
    out = np.asarray(paged_decode_attention(q, kp, vp, table, lens))
    assert np.isfinite(out).all()
    assert (out[0] == 0.0).all() and (out[2] == 0.0).all()
    assert np.abs(out[1]).max() > 0.0


def test_paged_decode_sub_page_bk_tiling():
    rng = np.random.default_rng(2)
    _, kp, vp, table, lens, _, _ = _build_paged(
        rng, (40, 7, 64), 16, 4, 2, 64, jnp.float32
    )
    q = _rand(jax.random.PRNGKey(2), (3, 4, 64), jnp.float32)
    full = paged_decode_attention(q, kp, vp, table, lens)
    for bk in (4, 8, 32):  # bk > ps clamps down to ps
        tiled = paged_decode_attention(q, kp, vp, table, lens, bk=bk)
        np.testing.assert_allclose(np.asarray(tiled), np.asarray(full), **TOL32)


def test_paged_decode_ref_dispatch():
    rng = np.random.default_rng(9)
    _, kp, vp, table, lens, _, _ = _build_paged(
        rng, (0, 11), 8, 2, 1, 32, jnp.float32
    )
    q = _rand(jax.random.PRNGKey(9), (2, 2, 32), jnp.float32)
    via_flag = paged_decode_attention(q, kp, vp, table, lens, use_pallas=False)
    np.testing.assert_array_equal(
        np.asarray(via_flag),
        np.asarray(paged_decode_attention_ref(q, kp, vp, table, lens)),
    )


def test_pack_prefill_pages_roundtrip():
    """pack -> gather returns the original rows (tail zero-padded)."""
    ps, hkv, d, s = 8, 2, 16, 21
    pool = PagedKVPool(n_pages=8, page_size=ps)
    kp, vp = init_page_arrays(pool.n_pages, ps, hkv, d, jnp.float32)
    pages = pool.alloc(0, s)
    k = jnp.asarray(np.random.default_rng(3).normal(size=(s, hkv, d)), jnp.float32)
    kp, vp = pack_prefill_pages(kp, vp, k, k * 2.0, jnp.asarray(pages, jnp.int32))
    table = jnp.asarray(pool.table([0], pages_for(s, ps)))
    got = gather_pages(kp, table)[0]
    np.testing.assert_array_equal(np.asarray(got[:s]), np.asarray(k))
    assert (np.asarray(got[s:]) == 0.0).all()
    np.testing.assert_array_equal(
        np.asarray(gather_pages(vp, table)[0][:s]), np.asarray(k) * 2.0
    )


def test_defrag_permutation_preserves_attention():
    rng = np.random.default_rng(4)
    pool, kp, vp, table, lens, _, _ = _build_paged(
        rng, (13, 5, 20, 7), 8, 3, 4, 32, jnp.float32
    )
    q = _rand(jax.random.PRNGKey(4), (4, 4, 32), jnp.float32)
    before = paged_decode_attention(q, kp, vp, table, lens)
    pool.free(1)
    pool.free(3)
    perm = pool.defrag()
    kp, vp = apply_page_permutation(kp, perm), apply_page_permutation(vp, perm)
    slot_rids = [0, None, 2, None]
    table2 = jnp.asarray(pool.table(slot_rids, 3))
    lens2 = jnp.asarray(pool.kv_lens(slot_rids))
    after = paged_decode_attention(q, kp, vp, table2, lens2)
    keep = np.array([0, 2])
    np.testing.assert_array_equal(np.asarray(after[keep]), np.asarray(before[keep]))
    assert (np.asarray(after[np.array([1, 3])]) == 0.0).all()
    # defrag left the pool compact: pages 1..in_use are exactly the owned set
    owned = sorted(p for r in pool.rids for p in pool.pages_of(r))
    assert owned == list(range(1, pool.in_use + 1))


# --------------------------------------------------------------------------- shared-oracle property
@settings(max_examples=15, deadline=None)
@given(
    ps=st.sampled_from([8, 16]),
    max_pages=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    lens_seed=st.integers(0, 2**16),
)
def test_both_kernels_share_one_ragged_oracle(ps, max_pages, group, hkv, lens_seed):
    """Dense `decode_attention` and the paged kernel vs ONE oracle, on the
    same ragged batch — kv_len drawn to include 0 and the full length S."""
    d = 32
    s = ps * max_pages
    rng = np.random.default_rng(lens_seed)
    b = int(rng.integers(2, 5))
    kv_lens = [0, s] + [int(rng.integers(0, s + 1)) for _ in range(b - 2)]
    _, kp, vp, table, lens, kd, vd = _build_paged(
        rng, tuple(kv_lens), ps, max_pages, hkv, d, jnp.float32
    )
    q = jnp.asarray(rng.normal(size=(b, group * hkv, d)), jnp.float32)
    oracle = ragged_decode_ref(q, kd, vd, lens)
    dense_out = decode_attention(q, kd, vd, lens, bk=ps)
    paged_out = paged_decode_attention(q, kp, vp, table, lens)
    np.testing.assert_allclose(np.asarray(dense_out), np.asarray(oracle), **TOL32)
    np.testing.assert_allclose(np.asarray(paged_out), np.asarray(oracle), **TOL32)
    zero = np.asarray(lens) == 0
    assert (np.asarray(dense_out)[zero] == 0.0).all()
    assert (np.asarray(paged_out)[zero] == 0.0).all()


# --------------------------------------------------------------------------- pool
def test_pool_alloc_free_reuse_and_stats():
    pool = PagedKVPool(n_pages=5, page_size=4)  # 4 usable pages
    p0 = pool.alloc(0, 6)  # 2 pages
    assert p0 is not None and len(p0) == 2 and NULL_PAGE not in p0
    pool.note_tokens(0, 6)
    assert pool.kv_len(0) == 6 and pool.capacity_tokens(0) == 8
    p1 = pool.alloc(1, 8)
    assert p1 is not None and not set(p0) & set(p1)
    assert pool.alloc(2, 5) is None  # all-or-nothing: 2 pages wanted, 0 left
    assert pool.stats().alloc_failures == 1
    assert 2 not in pool.rids  # refused alloc left no state behind
    freed = pool.free(0)
    assert freed == 2
    p2 = pool.alloc(2, 4)
    assert p2 is not None and set(p2) <= set(p0)  # LIFO reuse of hot pages
    st_ = pool.stats()
    assert st_.in_use == 3 and st_.free == 1
    assert st_.reused_pages >= 1 and st_.high_water == 4
    assert st_.frees == 2


def test_pool_append_extends_and_reports_oom():
    pool = PagedKVPool(n_pages=3, page_size=2)
    pool.alloc(0, 2)
    assert pool.append(0) and pool.append(0)  # fills page 1
    assert pool.append(0)  # auto-extends into the last free page
    assert pool.kv_len(0) == 3 and len(pool.pages_of(0)) == 2
    assert pool.append(0)  # fills page 2
    assert not pool.append(0)  # pool exhausted: reported, not raised
    assert pool.kv_len(0) == 4


def test_pool_guards():
    pool = PagedKVPool(n_pages=4, page_size=2)
    pool.alloc(7, 3)
    with pytest.raises(KeyError):
        pool.alloc(7, 1)  # double admission
    with pytest.raises(ValueError):
        pool.note_tokens(7, 5)  # beyond the 2-page reservation
    with pytest.raises(ValueError):
        pool.table_row(7, 1)  # table too narrow for the reservation
    with pytest.raises(ValueError):
        PagedKVPool(n_pages=1, page_size=2)  # only the null page
    row = pool.table_row(None, 3)
    assert (row == NULL_PAGE).all() and row.dtype == np.int32


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n_pages=st.sampled_from([5, 9, 17]))
def test_pool_churn_invariants(seed, n_pages):
    """Random admit/append/free/defrag churn never breaks the allocator:
    no page owned twice, the null page never granted, free + in_use
    conserved, and freed pages become allocatable again."""
    rng = np.random.default_rng(seed)
    pool = PagedKVPool(n_pages=n_pages, page_size=4)
    live: list[int] = []
    next_rid = 0
    for _ in range(60):
        op = rng.integers(4)
        if op == 0:
            pages = pool.alloc(next_rid, int(rng.integers(1, 9)))
            if pages is not None:
                live.append(next_rid)
            next_rid += 1
        elif op == 1 and live:
            pool.append(live[int(rng.integers(len(live)))], int(rng.integers(1, 3)))
        elif op == 2 and live:
            pool.free(live.pop(int(rng.integers(len(live)))))
        elif op == 3:
            perm = pool.defrag()
            assert perm[NULL_PAGE] == NULL_PAGE
            assert sorted(perm.tolist()) == list(range(n_pages))
        owned = [p for r in pool.rids for p in pool.pages_of(r)]
        assert len(owned) == len(set(owned)), "page owned twice"
        assert NULL_PAGE not in owned, "null page granted"
        assert len(owned) == pool.in_use
        assert pool.in_use + pool.stats().free == n_pages - 1
        assert set(pool.rids) == set(live)
    for rid in list(live):
        pool.free(rid)
    assert pool.in_use == 0 and pool.stats().free == n_pages - 1


# --------------------------------------------------------------------------- model integration
def test_model_paged_decode_matches_dense_decode():
    """decode_step_paged == decode_step when every slot is admitted at pos 0."""
    from repro.configs import RunConfig, smoke_config
    from repro.models.transformer import DecoderLM

    cfg = smoke_config("qwen25-3b")  # dense GQA smoke
    run = RunConfig(compute_dtype="float32", decode_cache_dtype="float32")
    model = DecoderLM(cfg, run)
    params = model.init(jax.random.PRNGKey(0))
    b, s, ps, max_pages = 3, 7, 8, 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    logits, cache_d = model.prefill(params, toks, max_len=ps * max_pages)

    pool = PagedKVPool(n_pages=1 + b * max_pages, page_size=ps)
    pcache = model.init_paged_cache(pool.n_pages, ps)
    kp, vp = pcache["layers"]["k"], pcache["layers"]["v"]
    for r in range(b):
        pages = pool.alloc(r, s + 3)
        pool.note_tokens(r, s)
        kp, vp = pack_prefill_pages(
            kp, vp, cache_d["layers"]["k"][:, r, :s], cache_d["layers"]["v"][:, r, :s],
            jnp.asarray(pages, jnp.int32),
        )
    pcache = {"layers": {"k": kp, "v": vp}}
    live = jnp.ones((b,), bool)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    slots = list(range(b))
    for _ in range(3):
        table = jnp.asarray(pool.table(slots, max_pages))
        lens = jnp.asarray(pool.kv_lens(slots))
        lg_d, cache_d = model.decode_step(params, cache_d, tok)
        lg_p, pcache = model.decode_step_paged(params, pcache, tok, table, lens, live)
        np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d), rtol=2e-4, atol=2e-4)
        for r in range(b):
            assert pool.append(r)
        tok = jnp.argmax(lg_d, -1).astype(jnp.int32)


def test_init_paged_cache_rejects_attention_free_families():
    from repro.configs import RunConfig, smoke_config
    from repro.models.transformer import DecoderLM

    model = DecoderLM(smoke_config("rwkv6-3b"), RunConfig())
    with pytest.raises(ValueError, match="paged"):
        model.init_paged_cache(8, 16)


# --------------------------------------------------------------------------- tuner model
def test_paged_tuner_model_cost_tradeoffs():
    from repro.power.tpu_model import DvfsState, TpuChipSpec

    model = paged_tuner_model(b=8, kv_mean=100.0)
    chip = TpuChipSpec()
    dvfs = DvfsState()
    assert set(model.search_space) == {"page_size", "bk", "depth"}
    t_small, c_small = model.model({"page_size": 32, "bk": 32, "depth": 2}, chip, dvfs)
    t_big, c_big = model.model({"page_size": 256, "bk": 128, "depth": 2}, chip, dvfs)
    # bigger pages over-fetch more bytes on ragged tails...
    assert c_big.hbm_bytes > c_small.hbm_bytes
    # ...while small pages pay more per-block issue latency
    t1, _ = model.model({"page_size": 32, "bk": 32, "depth": 1}, chip, dvfs)
    t4, _ = model.model({"page_size": 32, "bk": 32, "depth": 4}, chip, dvfs)
    assert t4 < t1
    assert t_small > 0 and t_big > 0
