"""Flight recorder, metrics registry, exporters, and instrumented call sites.

Unit tier for `repro.obs.trace` / `repro.obs.metrics` / `repro.obs.export`
plus end-to-end emission checks: with a recorder + registry installed,
the receiver, fleet monitor, governor, scheduler and fault ledger must
produce the documented series — and with nothing installed every call
site must stay a no-op.
"""
import io
import json

import pytest

from repro import obs
from repro.core import ConstantLoad, PowerSensor, make_device
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.export import (
    chrome_trace_events,
    chrome_trace_json,
    prometheus_text,
    write_chrome_trace,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import COUNTER, DEVICE, INSTANT, SPAN, WALL, TraceRecorder


@pytest.fixture(autouse=True)
def _no_global_obs():
    """Every test starts and ends with tracing disabled."""
    obs.disable()
    yield
    obs.disable()


# ------------------------------------------------------------ trace ring
def test_ring_wraps_and_counts_dropped():
    rec = TraceRecorder(capacity=8)
    for i in range(20):
        rec.instant(f"e{i}", t_us=i)
    assert len(rec) == 8
    assert rec.head == 20
    assert rec.dropped == 12
    # oldest-first, only the newest `capacity` events survive
    assert [e.name for e in rec.events()] == [f"e{i}" for i in range(12, 20)]


def test_ring_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        TraceRecorder(capacity=0)


def test_span_context_manager_records_wall_span():
    rec = TraceRecorder(capacity=16)
    with rec.span("work", track="loop", value=3.0):
        pass
    (ev,) = rec.events()
    assert ev.kind == SPAN and ev.kind_name == "span"
    assert ev.name == "work" and ev.track == "loop"
    assert ev.clock == WALL and ev.value == 3.0
    assert ev.dur_us >= 0 and ev.t1_us == ev.t_us + ev.dur_us


def test_span_at_clamps_negative_duration():
    rec = TraceRecorder(capacity=4)
    rec.span_at("x", 100, 50)
    assert rec.events()[0].dur_us == 0


def test_device_events_and_anchor_offset():
    rec = TraceRecorder(capacity=16)
    assert rec.device_offset_us() is None
    rec.device_span("k", 0.25, 0.30, track="attr", value=1.0)
    rec.device_instant("m", 0.275, track="attr")
    span, inst = rec.events()
    assert span.clock == DEVICE and span.t_us == 250_000 and span.dur_us == 50_000
    assert inst.kind == INSTANT and inst.t_us == 275_000
    assert rec.track_clock("attr") == DEVICE

    rec.anchor(2.0, wall_us=5_000_000)
    assert rec.device_offset_us() == 3_000_000
    rec.anchor_once(9.0, wall_us=1)  # no-op: an anchor already exists
    assert rec.anchors == [(5_000_000, 2_000_000)]


def test_counter_total_and_events_named():
    rec = TraceRecorder(capacity=16)
    rec.counter("rx.frames", 10.0, t_us=1)
    rec.counter("rx.frames", 32.0, t_us=2)
    rec.counter("rx.markers", 1.0, t_us=3)
    rec.instant("rx.frames", t_us=4)  # same name, not a counter sample
    assert rec.counter_total("rx.frames") == 42.0
    assert len(rec.events_named("rx.frames")) == 3
    assert all(e.kind == COUNTER for e in rec.events_named("rx.markers"))


def test_trace_install_uninstall_active():
    assert obs_trace.active() is None
    rec = obs_trace.install()
    assert obs_trace.active() is rec
    assert obs_trace.uninstall() is rec
    assert obs_trace.active() is None and obs_trace.uninstall() is None


# --------------------------------------------------------------- metrics
def test_counter_monotonic():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1.0)


def test_gauge_last_write_wins():
    g = Gauge()
    g.set(2.0)
    g.set(-7.5)
    assert g.value == -7.5


def test_histogram_buckets_and_quantiles():
    h = Histogram(lo=1e-3, hi=1.0, per_decade=2)
    for v in (2e-3, 5e-2, 5e-2, 0.9, 50.0):  # last one overflows
        h.observe(v)
    assert h.count == 5
    assert h.sum == pytest.approx(2e-3 + 0.1 + 0.9 + 50.0)
    bounds, cums = zip(*h.cumulative())
    assert bounds[-1] == float("inf") and cums[-1] == 5
    assert all(b <= a for a, b in zip(cums[1:], cums[:-1]))  # non-decreasing
    assert h.quantile(0.5) <= h.quantile(0.99)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_validation_and_empty_quantile():
    for bad in (dict(lo=0.0), dict(hi=1e-7), dict(per_decade=0)):
        with pytest.raises(ValueError):
            Histogram(**bad)
    assert Histogram().quantile(0.5) != Histogram().quantile(0.5)  # nan


def test_registry_labels_make_distinct_series():
    reg = MetricsRegistry()
    reg.counter("hits", device="dev0").inc(3)
    reg.counter("hits", device="dev1").inc(5)
    assert reg.get_value("hits", device="dev0") == 3.0
    assert reg.get_value("hits", device="dev1") == 5.0
    assert reg.get_value("hits") is None  # unlabelled series never created
    assert len(reg.series()) == 2


def test_registry_kind_mismatch_and_histogram_get_value():
    reg = MetricsRegistry()
    reg.counter("x", "a counter").inc()
    with pytest.raises(TypeError, match="already registered as counter"):
        reg.gauge("x")
    reg.histogram("lat_s").observe(0.1)
    assert reg.get_value("lat_s") is None  # histograms have no scalar value
    assert reg.help_text("x") == "a counter"


def test_metrics_install_uninstall_active():
    assert obs_metrics.active() is None
    reg = obs_metrics.install()
    assert obs_metrics.active() is reg
    assert obs_metrics.uninstall() is reg
    assert obs_metrics.active() is None


# -------------------------------------------------------------- exporters
def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("rx_frames_total", "frames decoded", device="dev0").inc(100)
    reg.gauge("fleet_power_w").set(123.5)
    reg.histogram("tick_s", "tick latency", lo=1e-3, hi=1.0).observe(0.01)
    text = prometheus_text(reg)
    assert "# HELP rx_frames_total frames decoded" in text
    assert "# TYPE rx_frames_total counter" in text
    assert 'rx_frames_total{device="dev0"} 100.0' in text
    assert "fleet_power_w 123.5" in text
    assert "# TYPE tick_s histogram" in text
    assert 'tick_s_bucket{le="+Inf"} 1' in text
    assert "tick_s_count 1" in text and "tick_s_sum 0.01" in text
    assert prometheus_text(MetricsRegistry()) == ""


def test_chrome_trace_device_fallback_without_anchor():
    rec = TraceRecorder(capacity=16)
    rec.device_instant("fault:dropout", 0.5, track="faults:dev0")
    evs = chrome_trace_events(rec)
    procs = {e["pid"]: e["args"]["name"]
             for e in evs if e["name"] == "process_name"}
    assert procs == {1: "repro", 2: "device-time"}
    (inst,) = [e for e in evs if e.get("ph") == "i"]
    assert inst["pid"] == 2 and inst["ts"] == 500_000  # raw device µs


def test_chrome_trace_anchored_alignment_and_counters():
    rec = TraceRecorder(capacity=16)
    rec.anchor(1.0, wall_us=rec.t0_us + 100)  # device 1.0 s == t0 + 100 µs
    rec.device_span("k", 1.0, 1.002, track="attr")
    rec.counter("rx.frames", 64.0, t_us=rec.t0_us + 40, track="rx")
    evs = chrome_trace_events(rec)
    assert all(e["pid"] == 1 for e in evs if e["name"] != "process_name")
    (span,) = [e for e in evs if e.get("ph") == "X"]
    assert span["ts"] == 100 and span["dur"] == 2000  # shifted onto wall
    (ctr,) = [e for e in evs if e.get("ph") == "C"]
    assert ctr["ts"] == 40 and ctr["args"] == {"rx.frames": 64.0}
    # distinct tracks get distinct named threads within the process
    named = {e["args"]["name"]: (e["pid"], e["tid"])
             for e in evs if e["name"] == "thread_name"}
    assert set(named) == {"attr", "rx"}
    assert len(set(named.values())) == 2


def test_chrome_trace_json_and_write(tmp_path):
    rec = TraceRecorder(capacity=2)
    for i in range(3):  # one event drops
        rec.instant(f"e{i}")
    text = chrome_trace_json(rec, metadata={"scenario": "unit"})
    doc = json.loads(text)
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"] == {
        "recorded_events": 3, "dropped_events": 1, "scenario": "unit",
    }
    p = tmp_path / "trace.json"
    write_chrome_trace(rec, str(p))
    assert json.loads(p.read_text())["traceEvents"]
    buf = io.StringIO()
    write_chrome_trace(rec, buf)
    assert json.loads(buf.getvalue())["otherData"]["recorded_events"] == 3


# ------------------------------------------------------- package plumbing
def test_enable_disable_roundtrip():
    rec, reg = obs.enable(capacity=32)
    assert obs_trace.active() is rec and rec.capacity == 32
    assert obs_metrics.active() is reg
    obs.disable()
    assert obs_trace.active() is None and obs_metrics.active() is None


def test_lazy_watch_attribute():
    mod = obs.watch
    assert hasattr(mod, "SignatureWatchdog")
    with pytest.raises(AttributeError, match="no attribute 'bogus'"):
        obs.bogus


# ------------------------------------------------- instrumented call sites
def test_host_emits_frame_counters_and_anchor():
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 1.0), seed=0)
    ps = PowerSensor(dev)
    try:
        ps.run_for(0.01)
        frames0 = ps._frame_count  # handshake-era frames predate tracing
        rec, _reg = obs.enable()
        ps.mark("S")
        ps.run_for(0.02)
        assert rec.counter_total("rx.frames") == float(ps._frame_count - frames0)
        assert rec.counter_total("rx.markers") >= 1.0
        assert rec.anchors, "receiver must anchor device time on first batch"
        track = rec.events_named("rx.frames")[0].track
        assert track.startswith("rx:")
    finally:
        ps.close()


def test_host_is_silent_when_disabled():
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 1.0), seed=0)
    ps = PowerSensor(dev)
    try:
        ps.run_for(0.02)  # no recorder installed: must simply not crash
    finally:
        ps.close()
    assert obs_trace.active() is None


def test_fleet_emits_power_and_health_series():
    from repro.faultlab import Disconnect, Scenario, inject
    from repro.stream import make_virtual_fleet

    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 2.0), ConstantLoad(12.0, 3.0)],
        window_s=0.02, lost_after_s=0.15,
    )
    rec, reg = obs.enable()
    inject(fleet, Scenario(faults=(Disconnect(0.1, 0.4, devices=("dev0",)),)))
    try:
        t = 0.0
        while t < 0.6 - 1e-12:
            fleet.advance(0.02)
            t += 0.02
            fleet.fleet_power()
    finally:
        fleet.close()
    assert reg.get_value("fleet_power_reads_total") == 30.0
    assert reg.get_value("fleet_power_w") > 0.0
    assert 0.0 < reg.get_value("fleet_quorum_frac") <= 1.0
    # the disconnected device's health walk lands on the transition counter
    assert reg.get_value("fleet_health_transitions_total",
                         device="dev0", to="stale") >= 1.0
    assert reg.get_value("fleet_health_transitions_total",
                         device="dev0", to="healthy") >= 1.0
    health_evs = [e for e in rec.events() if e.name.startswith("health:")]
    assert health_evs and all(e.track == "health:dev0" for e in health_evs)


def test_scheduler_emits_admission_and_settlement_series():
    from repro.sched import ContinuousBatch, EnergyPricer, Request, get_policy

    rec, reg = obs.enable()
    sched = ContinuousBatch(
        EnergyPricer(j_per_token=1.0), get_policy("throughput-max"), n_slots=2
    )
    sched.submit(Request(rid=0, client="a", gen_len=2))
    sched.submit(Request(rid=1, client="b", gen_len=2))
    sched.admit(0.0)
    for _ in range(2):
        sched.step_billing(1)
    sealed = sched.seal_interval()
    sched.settle_interval(sealed.index, 10.0)
    assert reg.get_value("sched_admitted_total") == 2.0
    assert reg.get_value("sched_intervals_sealed_total") == 1.0
    assert reg.get_value("sched_intervals_settled_total", mode="measured") == 1.0
    assert reg.get_value("sched_settled_joules_total") == 10.0
    names = {e.name for e in rec.events()}
    assert "sched:admit" in names
    assert f"sched:seal interval={sealed.index}" in names
    assert f"sched:settle interval={sealed.index}" in names


def test_governor_emits_tick_metrics():
    from repro.power import V5E
    from repro.sched import (
        GovernorConfig,
        OperatingGrid,
        PowerCapGovernor,
        VirtualPlant,
        decode_cost_of_batch,
    )

    grid = OperatingGrid(
        decode_cost_of_batch(80e6, 80e6, tokens_per_slot_step=8),
        n_layers=4, batches=(1, 2, 4, 8), tokens_per_slot_step=8,
    )
    rec, reg = obs.enable()
    plant = VirtualPlant(grid, n_devices=1, biases=[1.0], seed=0,
                         calibrate_samples=0)
    gov = PowerCapGovernor(
        plant, GovernorConfig(cap_w=0.8 * grid.max_watts, kp=0.15, ki=80.0)
    )
    try:
        gov.run(0.1, demand_of_t=lambda t: 8)
    finally:
        plant.close()
    ticks = reg.get_value("governor_ticks_total")
    assert ticks and ticks == float(len(gov.history))
    assert reg.get_value("governor_measured_w") >= V5E.p_static
    switch_evs = [e for e in rec.events()
                  if e.name.startswith("governor:switch")]
    if gov.n_switches:  # every switch shows up on the governor track
        assert len(switch_evs) == gov.n_switches
        assert all(e.track == "governor" for e in switch_evs)


def test_fault_ledger_obs_overlay():
    from repro.faultlab.transport import FaultLedger

    led = FaultLedger(
        device="dev3",
        dropped_spans=[(0.1, 0.2)],
        disconnect_spans=[(0.4, 0.5)],
        drift_spans=[(0.6, 0.7, 1.5)],
    )
    assert led.record_obs(None) == 0  # no recorder anywhere: clean no-op
    rec = TraceRecorder(capacity=16)
    assert led.record_obs(rec, epoch_s=1.0) == 3
    spans = {e.name: e for e in rec.events()}
    assert set(spans) == {"fault:dropout", "fault:disconnect", "fault:drift x1.5"}
    drop = spans["fault:dropout"]
    assert drop.clock == DEVICE and drop.track == "faults:dev3"
    assert drop.t_us == 1_100_000 and drop.dur_us == 100_000
    assert spans["fault:drift x1.5"].value == 1.5
