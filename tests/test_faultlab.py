"""Chaos test tier, part 1: the fault injector itself.

Property-based and scenario conformance tests asserting that the
injector's ground-truth ledger matches what the sensor stack reports:
energy error bounded by the injected dropout fraction (+1 %), no NaNs,
no negative joules, counters counting, markers surviving corruption.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.attrib import marker_spans
from repro.core import ConstantLoad, PowerSensor, make_device
from repro.faultlab import (
    ChaosRun,
    ClockDrift,
    Corruption,
    Disconnect,
    Dropout,
    FaultyTransport,
    PartialReads,
    Scenario,
    Stall,
    inject,
    periodic,
    shipped_scenarios,
)

DUR = 0.25


# --------------------------------------------------------------------- DSL
def test_fault_windows_validate():
    with pytest.raises(ValueError):
        Dropout(0.2, 0.1)
    with pytest.raises(ValueError):
        Corruption(0.0, 1.0, rate=1.5)
    with pytest.raises(ValueError):
        Corruption(0.0, 1.0, mode="meltdown")
    with pytest.raises(ValueError):
        ClockDrift(0.0, 1.0, factor=0.0)
    with pytest.raises(ValueError):
        PartialReads(0.0, 1.0, max_chunk=0)


def test_scenario_device_scoping_and_schedule():
    sc = Scenario(
        faults=(Disconnect(0.1, 0.2, devices=("dev1",)),),
        schedule=periodic(lambda t: Dropout(t, t + 0.01), 0.05, 3, start_s=0.3),
        name="mix",
    )
    assert len(sc.all_faults) == 4
    assert len(sc.faults_for("dev0")) == 3  # only the scheduled dropouts
    assert len(sc.faults_for("dev1")) == 4
    assert sc.end_s == pytest.approx(0.41)
    half = sc.scaled(0.5)
    assert half.end_s == pytest.approx(0.205)
    assert half.faults[0].devices == ("dev1",)


def test_fault_active_window_is_half_open():
    f = Dropout(0.1, 0.2)
    assert not f.active(0.0999)
    assert f.active(0.1)
    assert f.active(0.19999)
    assert not f.active(0.2)


# ------------------------------------------------------- shipped conformance
@pytest.mark.parametrize("name", sorted(shipped_scenarios(DUR)))
def test_shipped_scenario_conformance(name):
    """Every shipped scenario: energy within ledger bound, nothing silent."""
    sc = shipped_scenarios(DUR)[name]
    run = ChaosRun(sc, n_devices=2, seed=11)
    rep = run.run(DUR, mark_every_s=0.05)
    try:
        assert rep.check() == []
        for dev, out in rep.devices.items():
            led = rep.ledgers[dev]
            assert np.isfinite(out.reported_energy_j)
            assert out.reported_energy_j >= 0.0
            assert 0.0 <= led.delivered_frac <= 1.0 + 1e-9
            # the conformance bound restated explicitly: deviation from
            # ground truth <= injected dropout fraction + 1 % (+ explicit
            # corruption/pending allowances the ledger also records)
            assert out.deviation_frac <= rep.energy_bound_frac(dev, tol=0.01)
        # markers survive every scenario: spans parse, stay ordered, and
        # non-dropped occurrences carry positive durations
        for dev in rep.fleet.names:
            spans = marker_spans(rep.fleet[dev].markers, "C")
            assert all(s.t1_s >= s.t0_s for s in spans)
            ts = [s.t0_s for s in spans]
            assert ts == sorted(ts)
    finally:
        rep.close()


def test_injected_gaps_are_never_silent():
    """A dropout must surface in the ledger AND in the stack's own view."""
    sc = Scenario(faults=(Dropout(0.4 * DUR, 0.6 * DUR),), seed=3)
    run = ChaosRun(sc, n_devices=1, seed=5)
    rep = run.run(DUR)
    try:
        led = rep.ledgers["dev0"]
        assert led.dropped_frac == pytest.approx(0.2, abs=0.02)
        assert led.gap_spans(), "ledger lost the injected gap"
        # the ring exposes the same gap: one inter-frame step ~= the gap
        blk = rep.fleet["dev0"].ring.latest()
        assert np.diff(blk.times_s).max() == pytest.approx(
            0.2 * DUR, rel=0.1
        )
    finally:
        rep.close()


# ---------------------------------------------------------- property-based
@settings(max_examples=6, deadline=None)
@given(
    st.floats(min_value=0.1, max_value=0.5),
    st.floats(min_value=0.05, max_value=0.4),
    st.integers(min_value=0, max_value=1000),
)
def test_dropout_energy_bound_property(start_frac, width_frac, seed):
    """Random dropout windows: reported energy within dropout frac + 1 %."""
    t0 = start_frac * DUR
    t1 = min(t0 + width_frac * DUR, 0.95 * DUR)
    sc = Scenario(faults=(Dropout(t0, t1),), seed=seed)
    run = ChaosRun(sc, n_devices=1, seed=seed)
    rep = run.run(DUR)
    try:
        out = rep.devices["dev0"]
        led = rep.ledgers["dev0"]
        assert np.isfinite(out.reported_energy_j) and out.reported_energy_j >= 0
        assert out.deviation_frac <= led.dropped_frac + 0.01
        # and the ledger's ground truth matches the injected window
        assert led.dropped_frac == pytest.approx((t1 - t0) / DUR, abs=0.02)
    finally:
        rep.close()


@settings(max_examples=6, deadline=None)
@given(
    st.floats(min_value=1e-4, max_value=3e-3),
    st.integers(min_value=0, max_value=1000),
)
def test_corruption_never_nans_property(rate, seed):
    """Random corruption rates: energy finite, non-negative, frames counted."""
    sc = Scenario(faults=(Corruption(0.1 * DUR, 0.9 * DUR, rate=rate),), seed=seed)
    run = ChaosRun(sc, n_devices=1, seed=seed)
    rep = run.run(DUR)
    try:
        out = rep.devices["dev0"]
        led = rep.ledgers["dev0"]
        assert np.isfinite(out.reported_energy_j)
        assert out.reported_energy_j >= 0.0
        blk = rep.fleet["dev0"].ring.latest()
        assert np.isfinite(blk.watts).all()
        if led.corrupted_bytes:
            # corruption is visible, not silent: either resync discards or
            # a bounded energy deviation the ledger accounts for
            assert (
                out.dropped_frames > 0
                or out.deviation_frac <= rep.energy_bound_frac("dev0")
            )
    finally:
        rep.close()


# ----------------------------------------------------------- single faults
def _one_device(load_amps=4.0, seed=0):
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, load_amps), seed=seed)
    ps = PowerSensor(dev)
    return dev, ps


def test_stall_delays_but_never_loses():
    sc = Scenario(faults=(Stall(0.3 * DUR, 0.5 * DUR),), seed=4)
    run = ChaosRun(sc, n_devices=1, seed=7)
    rep = run.run(DUR)
    try:
        led = rep.ledgers["dev0"]
        assert led.stall_spans and led.dropped_spans == []
        assert led.delivered_frac == pytest.approx(1.0, abs=1e-3)
        assert rep.devices["dev0"].deviation_frac < 0.01
    finally:
        rep.close()


def test_partial_reads_reassemble_exactly():
    sc = Scenario(faults=(PartialReads(0.0, DUR, max_chunk=3),), seed=4)
    run = ChaosRun(sc, n_devices=1, seed=9)
    rep = run.run(DUR)
    try:
        assert rep.devices["dev0"].dropped_frames == 0
        assert rep.devices["dev0"].deviation_frac < 0.01
    finally:
        rep.close()


def test_disconnect_blocks_writes_and_recovers():
    dev, ps = _one_device()
    tr = FaultyTransport(dev, [Disconnect(0.05, 0.10)], name="dev0", seed=1)
    ps.device = tr
    tr.advance(0.06)
    ps.poll()
    ps.mark("A")  # falls inside the disconnect: command lost on the wire
    tr.advance(0.06)
    ps.poll()
    ps.mark("B")  # after reconnect: arrives
    tr.advance(0.02)
    ps.poll()
    assert tr.ledger.lost_writes == 1
    # exactly one marker bit reached the device (the lost command is the
    # ledger's to surface — the host can only label what arrived, and the
    # 1-bit wire marker cannot say *which* pending char it was)
    assert len(ps.markers) == 1
    assert tr.ledger.disconnect_spans == [(pytest.approx(0.05), pytest.approx(0.10))]


def test_gap_survives_time_reconstruction():
    """A multi-wrap gap must appear in ring time, not alias mod 1.024 ms."""
    dev, ps = _one_device()
    tr = FaultyTransport(dev, [Dropout(0.10, 0.155)], name="dev0", seed=1)
    ps.device = tr
    for _ in range(30):  # poll sparsely so the gap lands inside a batch too
        tr.advance(0.01)
        ps.poll()
    t = ps.ring.latest().times_s
    gaps = np.diff(t)
    assert (gaps >= 0).all()
    assert gaps.max() == pytest.approx(0.055, abs=0.002)
    assert abs(t[-1] - tr.t_s) < 2e-3  # re-anchored to the arrival clock


def test_clock_drift_skews_against_true_time():
    dev, ps = _one_device()
    tr = FaultyTransport(dev, [ClockDrift(0.0, 1.0, factor=0.9)], name="d", seed=1)
    ps.device = tr
    tr.advance(0.5)
    ps.poll()
    led = tr.ledger
    # the device delivered ~0.9 s of device-clock data per true second
    assert led.delivered_frac == pytest.approx(0.9, abs=0.02)
    assert led.drift_spans and led.drift_spans[0][2] == 0.9
    # the inner device clock fell behind the transport's true clock
    assert dev.t_s == pytest.approx(0.9 * tr.rel_t_s, rel=0.01)


def test_epoch_relative_fault_windows():
    """Scenario time counts from injection, not from device boot."""
    dev, ps = _one_device()
    ps.run_for(0.2)  # burn pre-chaos simulated time (like calibration does)
    tr = FaultyTransport(dev, [Dropout(0.0, 0.05)], name="dev0", seed=1)
    ps.device = tr
    before = ps.read().total_joules
    tr.advance(0.05)
    ps.poll()
    assert ps.read().total_joules == pytest.approx(before, rel=1e-6)
    tr.advance(0.05)
    ps.poll()
    assert ps.read().total_joules > before


def test_backlog_is_latency_not_gaps():
    """Size-capped reads delay frames; ring time must keep true 50 µs
    spacing (backlog is not a gap) and not run ahead after the drain."""
    dev, ps = _one_device()
    tr = FaultyTransport(
        dev, [PartialReads(0.0, 0.10, max_chunk=6)], name="d", seed=1
    )
    ps.device = tr
    t = 0.0
    while t < 0.2 - 1e-12:
        tr.advance(0.002)
        ps.poll()
        t += 0.002
    ps.poll()
    times = ps.ring.latest().times_s
    # during the backlog the reconstruction must not re-stamp delayed
    # frames to arrival time: spacing stays one frame everywhere
    assert np.diff(times).max() < 2e-4
    # and after the drain the clock is aligned, not projected ahead
    assert abs(times[-1] - tr.t_s) < 2e-3


def test_disabled_ch0_marker_frames_survive_split_reads():
    """Bare sensor-0 marker packets (ch0 disabled) make frames one packet
    longer; split reads must not strand their last channel packet."""
    from repro.core import ConstantLoad, PowerSensor, make_device

    dev = make_device([None, "pcie8pin-20a"], ConstantLoad(12.0, 3.0), seed=2)
    ps = PowerSensor(dev)
    tr = FaultyTransport(dev, [PartialReads(0.0, 1.0, max_chunk=5)], name="d", seed=3)
    ps.device = tr
    for _ in range(20):
        ps.mark("M")
        tr.advance(2e-4)  # a few frames
        for _ in range(40):  # drain through the 5-byte read cap
            ps.poll()
    tr.advance(2e-4)
    for _ in range(40):
        ps.poll()
    assert ps.dropped_frames == 0
    assert len(ps.markers) == 20


def test_corruption_marker_regression():
    """attrib.marker_spans survives a corrupted stream (no crash, ordered)."""
    dev, ps = _one_device()
    tr = FaultyTransport(
        dev, [Corruption(0.0, 1.0, rate=2e-3)], name="dev0", seed=3
    )
    ps.device = tr
    for k in range(10):
        ps.mark("W")
        tr.advance(0.02)
        ps.poll()
    spans = marker_spans(ps.markers, "W")
    assert all(s.duration_s >= 0 for s in spans)
    starts = [s.t0_s for s in spans]
    assert starts == sorted(starts)
    assert len(spans) <= 10  # corruption may eat markers, never invent order


# ---------------------------------------------- churn billing conformance
@pytest.mark.parametrize(
    "name", sorted(shipped_scenarios()), ids=lambda s: s
)
def test_shipped_scenario_churn_billing_conformance(name):
    """Every shipped chaos scenario through the live batch-mutation paths.

    A `ContinuousBatch` churn workload (staggered admissions, a mid-decode
    eviction, end-of-run settlement from step-interval attribution) runs
    against the faulted fleet; the contract is *consistency*, not
    accuracy — faults may shift marker windows, but every interval must
    end settled-or-released, the ledger must conserve billed + overhead
    == spent exactly, and no row may go non-finite or negative.  A clean
    scenario must additionally settle everything (zero released).
    """
    from repro.faultlab import churn_billing_run, shipped_scenarios as shipped

    report = churn_billing_run(shipped()[name])
    assert report.check() == [], report
    assert report.n_intervals > 0
    assert report.finished > 0  # churn actually served requests
    assert report.evicted == 1  # the mid-decode retirement happened
