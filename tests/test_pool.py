"""Pooled-decoder tier: the fused fleet decode must be **bit-identical**
to per-device polling, and the window-read hot path must be lock-free.

Two conformance angles:

* the committed golden corpus replayed through ``DeviceServer`` →
  ``FleetHead`` with the pooled path on and off, against the in-process
  per-device reference — rings, markers, drop counters, energy;
* a property sweep over randomized fleets — mixed channel configs,
  random poll schedules, deterministic resync junk — driving two
  identical virtual fleets (solo-polled vs pooled) and comparing every
  decoded artefact exactly.
"""
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.net import DeviceServer, FleetHead
from repro.replay import TraceArchive
from repro.replay.replay import ReplayDevice, replay_sensor
from repro.stream import FleetMonitor

GOLDEN_SCENARIOS = [
    "serve-wave",
    "serve-churn",
    "governor-step",
    "chaos-dropout",
    "chaos-disconnect",
]


# ------------------------------------------------------- golden conformance
def _drain_inprocess(trace):
    ps = replay_sensor(trace)
    ps.device.release_all()
    while True:
        if ps.poll() == 0 and (ps.device.exhausted or not ps.device.streaming):
            return ps


def _fingerprint(ps):
    blk = ps.ring.latest()
    return {
        "times": blk.times_s,
        "volts": blk.volts,
        "amps": blk.amps,
        "watts": blk.watts,
        "markers": list(ps.markers),
        "dropped_bytes": ps.dropped_bytes,
        "dropped_frames": ps.dropped_frames,
        "joules": ps.read().consumed_joules,
    }


def _drain_fleethead(arc, pooled):
    """All of one archive's devices through DeviceServer → FleetHead."""
    cap = max(
        max(1 << max(len(tr) - 1, 1).bit_length(), 1024)
        for tr in arc.devices.values()
    )
    srv = DeviceServer({nm: ReplayDevice(tr) for nm, tr in arc.devices.items()})
    head = FleetHead(
        {nm: srv.endpoint for nm in arc.devices},
        reconnect=False,
        pooled=pooled,
        ring_capacity=cap,
    )
    try:
        for nm, tr in arc.devices.items():
            head[nm].expect_markers(tr.marker_chars)
        import time as _time

        deadline = _time.monotonic() + 60.0
        while _time.monotonic() < deadline:
            head.poll()
            if all(head[nm].device.exhausted for nm in arc.devices):
                break
        assert all(head[nm].device.exhausted for nm in arc.devices)
        while head.poll():
            pass
        out = {nm: _fingerprint(head[nm]) for nm in arc.devices}
        if pooled:
            assert head.monitor.pool is not None
            assert head.monitor.pool.polls > 0
            out["__fused_frames__"] = head.monitor.pool.fused_frames
        return out
    finally:
        head.close()
        srv.close()


def _assert_same(ref_fp, got_fp, ctx):
    assert np.array_equal(ref_fp["times"], got_fp["times"]), ctx
    assert np.array_equal(ref_fp["volts"], got_fp["volts"]), ctx
    assert np.array_equal(ref_fp["amps"], got_fp["amps"]), ctx
    assert np.array_equal(ref_fp["watts"], got_fp["watts"]), ctx
    assert ref_fp["markers"] == got_fp["markers"], ctx
    assert ref_fp["dropped_bytes"] == got_fp["dropped_bytes"], ctx
    assert ref_fp["dropped_frames"] == got_fp["dropped_frames"], ctx
    assert ref_fp["joules"] == got_fp["joules"], ctx


@pytest.mark.parametrize("scenario", GOLDEN_SCENARIOS)
def test_golden_fleethead_pooled_matches_inprocess(scenario):
    arc = TraceArchive.load(f"tests/goldens/{scenario}.npz")
    refs = {
        nm: _fingerprint(_drain_inprocess(tr)) for nm, tr in arc.devices.items()
    }
    for pooled in (False, True):
        got = _drain_fleethead(arc, pooled)
        for nm, ref_fp in refs.items():
            _assert_same(ref_fp, got[nm], (scenario, nm, pooled))
    # the clean steady-stream scenario must actually exercise the fused
    # path (otherwise this whole test silently pins only the fallback)
    if scenario == "serve-wave":
        assert got["__fused_frames__"] > 0


# ------------------------------------------------------- property sweep
_CONFIGS = [
    ["pcie8pin-20a"],
    ["pcie8pin-20a", "usb-c"],
    ["gp-20a", None, "slot-10a-12v"],
    ["hc-50a", "slot-10a-3v3", None, "usb-c"],
]


class _JunkDevice:
    """Wrap a VirtualDevice; deterministically inject resync junk.

    Junk draws come from a private seeded RNG consulted only after
    ``arm()`` (never during the handshake), so two wrappers built with
    the same seed corrupt identical byte positions — the solo and pooled
    fleets see the exact same wire bytes.
    """

    def __init__(self, inner, seed: int, rate: float):
        self._inner = inner
        self._rng = np.random.default_rng(seed)
        self._rate = float(rate)
        self._armed = False

    def arm(self) -> None:
        self._armed = True

    def write(self, data: bytes) -> None:
        self._inner.write(data)

    def read(self, max_bytes=None) -> bytes:
        data = self._inner.read(max_bytes)
        if self._armed and self._rate > 0.0 and data:
            if self._rng.random() < self._rate:
                n = int(self._rng.integers(1, 5))
                junk = bytes(
                    np.asarray(self._rng.integers(0, 128, size=n), dtype=np.uint8)
                )
                data = junk + data if self._rng.random() < 0.5 else data + junk
        return data

    def advance(self, dt_s: float) -> None:
        self._inner.advance(dt_s)

    @property
    def t_s(self) -> float:
        return self._inner.t_s


def _build_fleet(cfg_idx, junk_seed, junk_rate):
    sensors = {}
    for i, ci in enumerate(cfg_idx):
        inner = make_device(
            _CONFIGS[ci], ConstantLoad(12.0, 1.0 + i), seed=1000 + i
        )
        dev = _JunkDevice(inner, seed=7919 * junk_seed + i, rate=junk_rate)
        sensors[f"dev{i}"] = PowerSensor(dev, ring_capacity=1 << 14)
        dev.arm()
    return sensors


@settings(max_examples=8, deadline=None)
@given(
    cfg_idx=st.lists(
        st.integers(0, len(_CONFIGS) - 1), min_size=2, max_size=4
    ),
    dts=st.lists(
        st.floats(min_value=0.0004, max_value=0.004), min_size=4, max_size=10
    ),
    marks=st.lists(st.booleans(), min_size=10, max_size=10),
    junk_seed=st.integers(0, 1 << 16),
    junk_rate=st.sampled_from([0.0, 0.0, 0.3]),
)
def test_pooled_decode_bit_identical_to_solo(
    cfg_idx, dts, marks, junk_seed, junk_rate
):
    solo = _build_fleet(cfg_idx, junk_seed, junk_rate)
    pooled = _build_fleet(cfg_idx, junk_seed, junk_rate)
    mon = FleetMonitor(pooled)
    mon.enable_pool()

    for k, dt in enumerate(dts):
        for fleet in (solo, pooled):
            for ps in fleet.values():
                ps.device.advance(dt)
                if marks[k % len(marks)]:
                    ps.mark("S")
        for ps in solo.values():
            ps.poll()
        mon.poll_all()
    for ps in solo.values():
        ps.poll()
    mon.poll_all()

    for name, ref in solo.items():
        got = mon[name]
        _assert_same(_fingerprint(ref), _fingerprint(got), name)
        assert ref._residual == got._residual, name
        assert ref._last_ts10 == got._last_ts10, name
        assert ref._device_time_us == got._device_time_us, name
    if junk_rate == 0.0:
        # clean streams must land on the fused path, not the fallback
        assert mon.pool.fused_frames > 0


# ------------------------------------------------------- lock-free readers
def test_window_reads_do_not_take_receiver_lock():
    """Regression: `fleet_power` / `tail_mean_watts` must complete while
    the receiver lock is held (pre-seqlock they deadlocked behind it)."""
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 2.0))
    ps = PowerSensor(dev)
    dev.advance(0.05)
    ps.poll()
    mon = FleetMonitor({"dev0": ps})
    got = {}

    def _reader():
        got["tail"] = ps.ring.tail_mean_watts(0.01)
        got["fleet"] = mon.fleet_power(poll=False).raw_power_w

    with ps._lock:  # a wedged/long receiver append holds this
        t = threading.Thread(target=_reader, daemon=True)
        t.start()
        t.join(2.0)
        assert not t.is_alive(), "window read blocked on the receiver lock"
    assert got["tail"] > 0.0
    assert np.isfinite(got["fleet"])


def test_pool_poll_surfaces_transport_errors_per_device():
    """One dead link must not poison the other links' pooled decode."""

    class _DeadDevice:
        t_s = 0.0
        pending_bytes = 0

        def write(self, data):
            pass

        def read(self, max_bytes=None):
            raise ConnectionError("link down")

        def advance(self, dt_s):
            pass

    good_inner = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 2.0))
    good = PowerSensor(good_inner)
    mon = FleetMonitor({"good": good})
    # a healthy handshake whose transport then dies: swap the device out
    bad = PowerSensor(make_device(["pcie8pin-20a"], ConstantLoad(12.0, 1.0)))
    bad.device = _DeadDevice()
    mon.add("bad", bad)
    mon.enable_pool()
    good_inner.advance(0.02)
    n = mon.poll_all()
    assert n > 0  # the good link's frames landed
    assert "bad" in mon.poll_errors
    h = mon.device_health()
    assert h["good"].state == "healthy"
