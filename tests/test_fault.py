"""Fault tolerance: bitwise-transparent crash/resume, stragglers, preemption."""
import os
import signal

import jax
import numpy as np
import pytest

from repro.configs import RunConfig, smoke_config
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import FaultInjector, LoopConfig, StragglerWatchdog, train
from repro.train.fault import SimulatedPreemption

RUN = RunConfig(attn_impl="full", remat="none", lr_chunk=8)


def _setup(seed=3):
    cfg = smoke_config("qwen25_3b")
    model = build_model(cfg, RUN)
    data = SyntheticTokens(cfg, global_batch=4, seq_len=32, seed=seed)
    return cfg, model, data


def test_crash_resume_bitwise_identical(tmp_path):
    """Crash at step 12, resume from the step-10 checkpoint, finish; the
    final params must equal an uninterrupted run bit for bit."""
    opt = AdamWConfig(lr=2e-3, warmup_steps=2, total_steps=20)

    # uninterrupted reference
    cfg, model, data = _setup()
    ref = train(model, data, opt, LoopConfig(steps=20, log_every=0, ckpt_every=0))

    # crashing run with checkpoints every 5
    cfg, model, data = _setup()
    d = str(tmp_path / "ck")
    loop = LoopConfig(steps=20, log_every=0, ckpt_every=5, ckpt_dir=d,
                      async_checkpoint=False)
    res1 = train(model, data, opt, loop, fault_injector=FaultInjector(crash_at_step=12))
    assert res1.preempted and res1.stopped_at < 20

    # fresh process-equivalent resume (new model object, same config)
    cfg, model, data = _setup()
    res2 = train(model, data, opt, loop)
    assert res2.stopped_at == 20

    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(res2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_deterministic_resume():
    cfg, _, data = _setup(seed=9)
    b5 = data.batch_at(5)
    data2 = SyntheticTokens(cfg, global_batch=4, seq_len=32, seed=9)
    data2.load_state_dict({"step": 5, "seed": 9, "host_id": 0})
    np.testing.assert_array_equal(b5["tokens"], data2.batch_at(5)["tokens"])


def test_host_sharded_pipeline_partition():
    """Two hosts' slices together must equal the single-host batch set
    (disjoint, deterministic)."""
    cfg = smoke_config("qwen25_3b")
    h0 = SyntheticTokens(cfg, global_batch=8, seq_len=16, seed=1, host_id=0, n_hosts=2)
    h1 = SyntheticTokens(cfg, global_batch=8, seq_len=16, seed=1, host_id=1, n_hosts=2)
    b0, b1 = h0.batch_at(0)["tokens"], h1.batch_at(0)["tokens"]
    assert b0.shape == (4, 17) and b1.shape == (4, 17)
    assert not np.array_equal(b0, b1)


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=2.0, warmup=3)
    for i in range(10):
        assert not w.observe(i, 0.1)
    assert w.observe(10, 0.5)  # 5x the EWMA
    assert len(w.events) == 1
    # a straggler must not pollute the EWMA
    assert abs(w.ewma - 0.1) < 0.02


def test_fault_injector_one_shot():
    fi = FaultInjector(crash_at_step=3)
    fi.check(2)
    with pytest.raises(SimulatedPreemption):
        fi.check(3)
    fi.check(3)  # does not re-raise


def test_sigterm_checkpoint_and_exit(tmp_path):
    """SIGTERM mid-training -> clean checkpoint + preempted flag."""
    opt = AdamWConfig(lr=1e-3, total_steps=50)
    cfg, model, data = _setup()
    d = str(tmp_path / "ck")

    class SignalAt:
        def __init__(self, at):
            self.at = at

        def check(self, step):
            if step == self.at:
                os.kill(os.getpid(), signal.SIGTERM)

    loop = LoopConfig(steps=50, log_every=0, ckpt_every=0, ckpt_dir=d,
                      async_checkpoint=False)
    res = train(model, data, opt, loop, fault_injector=SignalAt(4))
    assert res.preempted
    from repro.train import checkpoint as ckpt

    assert ckpt.latest_step(d) == 5  # checkpointed at the step boundary
