"""Optimizer + gradient compression unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import (
    AdamWConfig,
    ErrorFeedbackCompressor,
    apply_updates,
    dequantize_int8,
    init_opt_state,
    quantize_int8,
    schedule_lr,
)


def _params():
    return {"a": jnp.ones((4, 4)), "b": {"c": jnp.full((3,), 2.0)}}


def test_adamw_descends_quadratic():
    params = {"x": jnp.array([5.0, -3.0])}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0,
                      clip_norm=0.0, schedule="constant")
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, _ = apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_weight_decay_shrinks_params():
    params = _params()
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.5, schedule="constant")
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = apply_updates(params, zeros, state, cfg)
    assert float(new["a"][0, 0]) < 1.0


def test_clipping_caps_update():
    params = {"x": jnp.zeros((2,))}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, warmup_steps=0, clip_norm=1.0, weight_decay=0.0,
                      schedule="constant")
    huge = {"x": jnp.full((2,), 1e6)}
    _, _, stats = apply_updates(params, huge, state, cfg)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine",
                      min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.int32(s))) for s in (0, 9, 10, 50, 99)]
    assert lrs[0] < lrs[1] <= lrs[2]  # warmup increasing
    assert lrs[2] == pytest.approx(1.0, rel=0.05)
    assert lrs[-1] == pytest.approx(0.1, rel=0.1)  # floor


def test_opt_state_structure():
    params = _params()
    st_ = init_opt_state(params)
    assert set(st_) == {"m", "v", "step"}
    assert jax.tree.structure(st_["m"]) == jax.tree.structure(params)


# ------------------------------------------------------------- compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_int8_quant_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_unbiased_over_time():
    """EF: the *sum* of decompressed grads tracks the sum of true grads."""
    comp = ErrorFeedbackCompressor()
    rng = np.random.default_rng(0)
    total_true = np.zeros(32)
    total_sent = np.zeros(32)
    for _ in range(50):
        g = {"w": jnp.asarray(rng.standard_normal(32), jnp.float32)}
        out, _ = comp.compress_decompress(g)
        total_true += np.asarray(g["w"])
        total_sent += np.asarray(out["w"])
    resid = np.abs(total_true - total_sent).max()
    # residual is bounded by one quantisation step, not growing with steps
    assert resid < 0.2


def test_compression_ratio():
    comp = ErrorFeedbackCompressor()
    g = {"w": jnp.ones((1024,), jnp.float32)}
    _, wire = comp.compress_decompress(g)
    assert wire < ErrorFeedbackCompressor.uncompressed_bytes(g) / 3.5
