"""Property-based wire-protocol codec tests: random frame batches,
resync after injected garbage, and split-across-read packet boundaries.

Runs under real `hypothesis` when installed, else under the deterministic
shim from ``tests/conftest.py`` (same strategies, bounded examples).
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import protocol

# one frame: (10-bit timestamp value, [(channel id 0..6, 10-bit value, marker)])
FRAMES = st.lists(
    st.tuples(
        st.integers(0, 1023),
        st.lists(
            st.tuples(st.integers(0, 6), st.integers(0, 1023), st.integers(0, 1)),
            min_size=0,
            max_size=8,
        ),
    ),
    min_size=1,
    max_size=16,
)


def _flatten(frames):
    """Frame batches -> (ids, vals, marks) arrays, as the firmware emits."""
    ids, vals, marks = [], [], []
    for ts_val, chans in frames:
        ids.append(protocol.TIMESTAMP_SENSOR_ID)
        vals.append(ts_val)
        marks.append(1)
        for cid, val, mark in chans:
            ids.append(cid)
            vals.append(val)
            marks.append(mark)
    return np.array(ids), np.array(vals), np.array(marks)


@settings(max_examples=100, deadline=None)
@given(FRAMES)
def test_roundtrip_random_frame_batches(frames):
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    dids, dvals, dmarks, consumed = protocol.decode_packets(raw)
    assert consumed == len(raw)
    np.testing.assert_array_equal(dids, ids)
    np.testing.assert_array_equal(dvals, vals)
    np.testing.assert_array_equal(dmarks, marks)
    # timestamp packets stay exactly where the frame structure put them
    is_ts = protocol.is_timestamp(dids, dmarks)
    expected_ts = (ids == protocol.TIMESTAMP_SENSOR_ID) & (marks == 1)
    np.testing.assert_array_equal(is_ts, expected_ts)


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.integers(0, 15), st.integers(1, 4))
def test_resync_after_orphan_garbage_bytes(frames, pos_seed, n_garbage):
    """Orphan second-bytes (bit7 clear) injected at a packet boundary are
    dropped and every real packet is still decoded."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    cut = 2 * (pos_seed % (len(ids) + 1))  # an even offset = packet boundary
    garbage = bytes([0x55 & 0x7F] * n_garbage)  # bit7 clear: orphan seconds
    noisy = raw[:cut] + garbage + raw[cut:]
    dids, dvals, dmarks, consumed = protocol.decode_packets(noisy)
    np.testing.assert_array_equal(dids, ids)
    np.testing.assert_array_equal(dvals, vals)
    np.testing.assert_array_equal(dmarks, marks)
    # garbage *between* packets is consumed with them; garbage trailing the
    # last packet may be held back — but retrying the residual (as the host
    # receiver does) must drain it without fabricating packets
    assert consumed >= len(noisy) - n_garbage
    rest_ids, _, _, rest_consumed = protocol.decode_packets(noisy[consumed:])
    assert len(rest_ids) == 0
    assert rest_consumed == len(noisy) - consumed


def _is_subsequence(needle, haystack):
    it = iter(haystack)
    return all(x in it for x in needle)


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.integers(0, 15), st.integers(0, 255))
def test_arbitrary_garbage_never_destroys_real_packets(frames, pos_seed, byte):
    """A single arbitrary garbage byte may fabricate at most one bogus
    packet but every real packet survives (resync on the flag bits)."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    cut = 2 * (pos_seed % (len(ids) + 1))
    noisy = raw[:cut] + bytes([byte]) + raw[cut:]
    dids, dvals, dmarks, _ = protocol.decode_packets(noisy)
    real = list(zip(ids.tolist(), vals.tolist(), marks.tolist()))
    got = list(zip(dids.tolist(), dvals.tolist(), dmarks.tolist()))
    assert _is_subsequence(real, got)
    assert len(got) <= len(real) + 1


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.lists(st.integers(1, 7), min_size=1, max_size=8))
def test_split_across_reads_reassembles_exactly(frames, chunk_sizes):
    """Chunked reads with arbitrary (odd!) split points reassemble through
    the residual-buffer discipline the host receiver uses."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    # carve the byte stream into chunks, cycling the given sizes
    chunks = []
    i = k = 0
    while i < len(raw):
        n = chunk_sizes[k % len(chunk_sizes)]
        chunks.append(raw[i : i + n])
        i += n
        k += 1
    residual = b""
    out_ids, out_vals, out_marks = [], [], []
    for chunk in chunks:
        buf = residual + chunk
        dids, dvals, dmarks, consumed = protocol.decode_packets(buf)
        residual = buf[consumed:]
        out_ids.extend(dids.tolist())
        out_vals.extend(dvals.tolist())
        out_marks.extend(dmarks.tolist())
    assert residual == b""
    np.testing.assert_array_equal(out_ids, ids)
    np.testing.assert_array_equal(out_vals, vals)
    np.testing.assert_array_equal(out_marks, marks)


@settings(max_examples=50, deadline=None)
@given(FRAMES)
def test_trailing_first_byte_left_unconsumed(frames):
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    truncated = raw[:-1]  # drop the final second-byte
    dids, _, _, consumed = protocol.decode_packets(truncated)
    assert consumed == len(raw) - 2  # the dangling first byte is kept back
    assert len(dids) == len(ids) - 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 127), min_size=0, max_size=32))
def test_pure_orphan_stream_decodes_nothing(seconds):
    """A stream of nothing but second-bytes consumes fully, yields nothing."""
    buf = bytes(seconds)
    dids, dvals, dmarks, consumed = protocol.decode_packets(buf)
    assert len(dids) == 0
    assert consumed == len(buf)


# ---------------------------------------------------------------------------
# host-level dropped-frame accounting: the receiver counts what it discards
# ---------------------------------------------------------------------------
class _ScriptedDevice:
    """Minimal device stub: answers the connect handshake, then streams
    whatever bytes the test feeds it (so garbage can be injected at exact
    byte offsets, which a real firmware emulator never produces)."""

    def __init__(self, n_enabled=2):
        self._out = bytearray()
        self._n_enabled = n_enabled
        self.t_s = 0.0

    def write(self, data: bytes) -> None:
        i = 0
        while i < len(data):
            c = data[i : i + 1]
            if c == protocol.CMD_VERSION:
                self._out += b"scripted\0"
                i += 1
            elif c == protocol.CMD_READ_CONFIG:
                sid = data[i + 1]
                self._out += protocol.SensorConfigBlock(
                    name=f"ch{sid}",
                    type_code=sid % 2,
                    enabled=sid < self._n_enabled,
                    vref=3.3,
                    sensitivity=1.0,
                ).pack()
                i += 2
            elif c == protocol.CMD_MARKER:
                i += 2
            else:  # start/stop stream etc.: no reply
                i += 1

    def read(self, max_bytes=None) -> bytes:
        out = bytes(self._out)
        self._out.clear()
        return out

    def advance(self, dt_s: float) -> None:
        self.t_s += dt_s

    def feed(self, raw: bytes) -> None:
        self._out += raw


def _frame_stream(n_frames, n_enabled=2):
    """A clean [ts, ch0, ch1, ...] packet stream, 50 µs frame spacing."""
    ids, vals, marks = [], [], []
    for k in range(n_frames):
        ids.append(protocol.TIMESTAMP_SENSOR_ID)
        vals.append((25 + 50 * k) % 1024)
        marks.append(1)
        for ch in range(n_enabled):
            ids.append(ch)
            vals.append(500 + ch)
            marks.append(0)
    return protocol.encode_packets(
        np.array(ids), np.array(vals), np.array(marks)
    )


def _host(n_enabled=2):
    from repro.core.host import PowerSensor

    return PowerSensor(_ScriptedDevice(n_enabled))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(0, 31), st.integers(1, 5))
def test_clean_chunked_stream_never_counts_drops(n_frames, split_seed, chunk):
    """However a clean stream is split across reads, nothing is 'dropped'."""
    ps = _host()
    raw = _frame_stream(n_frames)
    i = 0
    while i < len(raw):
        n = 1 + (split_seed + i) % (2 * chunk)
        ps.device.feed(raw[i : i + n])
        i += n
        ps.poll()
    ps.poll()
    assert ps.dropped_frames == 0
    assert ps.dropped_bytes == 0
    # and every complete frame eventually landed (the trailing frame may be
    # held back awaiting its successor's timestamp)
    assert ps.ring.head >= n_frames - 1


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(1, 4))
def test_one_byte_reads_never_count_drops(n_frames, n_enabled):
    """The pathological transport: every read returns a single byte, so
    *every* poll ends mid-packet and mid-frame, and the held-back trailing
    frame re-enters the buffer on every single poll.  The junk accounting
    must stay exactly 0 the whole way — a held-back frame re-consumed is
    not a discard."""
    ps = _host(n_enabled)
    raw = _frame_stream(n_frames, n_enabled)
    for i in range(len(raw)):
        ps.device.feed(raw[i : i + 1])
        ps.poll()
        # the invariant holds at every step, not just at the end
        assert ps.dropped_bytes == 0
        assert ps.dropped_frames == 0
    assert ps.ring.head >= n_frames - 1
    # the residual holds (at most) the held-back trailing frame — raw
    # bytes, so one more frame's worth of feed drains it losslessly
    assert len(ps._residual) <= 2 * (1 + n_enabled)


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 10), st.integers(0, 9), st.integers(1, 4))
def test_one_byte_reads_with_garbage_count_exactly(n_frames, pos_seed, n_garbage):
    """Garbage injected into a 1-byte-read stream: the resync discard is
    counted exactly once even though the tail frame around it is held back
    and re-consumed (the re-encode fallback path)."""
    ps = _host()
    raw = _frame_stream(n_frames)
    cut = 2 * (1 + pos_seed % (len(raw) // 2 - 1))  # mid-stream boundary
    noisy = raw[:cut] + bytes([0x55] * n_garbage) + raw[cut:]
    for i in range(len(noisy)):
        ps.device.feed(noisy[i : i + 1])
        ps.poll()
    assert ps.dropped_bytes == n_garbage
    assert ps.ring.head >= n_frames - 1


@settings(max_examples=10, deadline=None)
@given(st.integers(3, 8))
def test_one_byte_reads_disabled_ch0_bare_markers(n_frames):
    """1-byte reads over a stream whose ch0 is disabled but still carries
    bare sensor-0 marker packets (the `expected + 1` hold-back sizing):
    still zero drops, and every marker bit survives reassembly."""
    ps = _host(n_enabled=2)
    # disable ch0 on the host side only: the scripted stream below emits
    # a bare marked sensor-0 packet right after each timestamp
    ps.configs[0] = ps.configs[0].__class__(
        name="ch0", type_code=0, enabled=False, vref=3.3, sensitivity=1.0
    )
    ps._refresh_conversion()
    ids, vals, marks = [], [], []
    for k in range(n_frames):
        ids += [protocol.TIMESTAMP_SENSOR_ID, 0, 1]
        vals += [(25 + 50 * k) % 1024, 0, 501]
        marks += [1, 1, 0]
    raw = protocol.encode_packets(np.array(ids), np.array(vals), np.array(marks))
    ps.expect_markers("M" * n_frames)
    for i in range(len(raw)):
        ps.device.feed(raw[i : i + 1])
        ps.poll()
    assert ps.dropped_bytes == 0
    assert ps.dropped_frames == 0
    assert len(ps.markers) >= n_frames - 1


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(0, 15), st.integers(1, 6))
def test_orphan_garbage_increments_dropped_frames(n_frames, pos_seed, n_garbage):
    """Injected orphan bytes are discarded AND counted, never silent."""
    ps = _host()
    raw = _frame_stream(n_frames)
    cut = 2 * (pos_seed % (len(raw) // 2 + 1))
    ps.device.feed(raw[:cut] + bytes([0x55] * n_garbage) + raw[cut:])
    ps.poll()
    ps.poll()
    assert ps.dropped_bytes == n_garbage
    assert ps.dropped_frames == (n_garbage + 1) // 2
    # the real frames all survive resync (minus the held-back tail)
    assert ps.ring.head >= n_frames - 1


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 12), st.integers(1, 4))
def test_headless_data_packets_are_counted(n_frames, n_eaten):
    """Frames whose timestamp was eaten lose their data packets — counted."""
    ps = _host()
    raw = _frame_stream(n_frames)
    # delete the first n_eaten timestamps' 2-byte packets (frame = 3 packets)
    arr = bytearray(raw)
    for k in range(n_eaten):
        ts_at = k * 6 - 2 * k  # each prior deletion shifts by 2
        del arr[ts_at : ts_at + 2]
    ps.device.feed(bytes(arr))
    ps.poll()
    ps.poll()
    # 2 data packets per eaten timestamp arrive with no frame to join
    assert ps.dropped_frames >= n_eaten
    assert ps.ring.head >= n_frames - n_eaten - 1
