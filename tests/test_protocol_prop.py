"""Property-based wire-protocol codec tests: random frame batches,
resync after injected garbage, and split-across-read packet boundaries.

Runs under real `hypothesis` when installed, else under the deterministic
shim from ``tests/conftest.py`` (same strategies, bounded examples).
"""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import protocol

# one frame: (10-bit timestamp value, [(channel id 0..6, 10-bit value, marker)])
FRAMES = st.lists(
    st.tuples(
        st.integers(0, 1023),
        st.lists(
            st.tuples(st.integers(0, 6), st.integers(0, 1023), st.integers(0, 1)),
            min_size=0,
            max_size=8,
        ),
    ),
    min_size=1,
    max_size=16,
)


def _flatten(frames):
    """Frame batches -> (ids, vals, marks) arrays, as the firmware emits."""
    ids, vals, marks = [], [], []
    for ts_val, chans in frames:
        ids.append(protocol.TIMESTAMP_SENSOR_ID)
        vals.append(ts_val)
        marks.append(1)
        for cid, val, mark in chans:
            ids.append(cid)
            vals.append(val)
            marks.append(mark)
    return np.array(ids), np.array(vals), np.array(marks)


@settings(max_examples=100, deadline=None)
@given(FRAMES)
def test_roundtrip_random_frame_batches(frames):
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    dids, dvals, dmarks, consumed = protocol.decode_packets(raw)
    assert consumed == len(raw)
    np.testing.assert_array_equal(dids, ids)
    np.testing.assert_array_equal(dvals, vals)
    np.testing.assert_array_equal(dmarks, marks)
    # timestamp packets stay exactly where the frame structure put them
    is_ts = protocol.is_timestamp(dids, dmarks)
    expected_ts = (ids == protocol.TIMESTAMP_SENSOR_ID) & (marks == 1)
    np.testing.assert_array_equal(is_ts, expected_ts)


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.integers(0, 15), st.integers(1, 4))
def test_resync_after_orphan_garbage_bytes(frames, pos_seed, n_garbage):
    """Orphan second-bytes (bit7 clear) injected at a packet boundary are
    dropped and every real packet is still decoded."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    cut = 2 * (pos_seed % (len(ids) + 1))  # an even offset = packet boundary
    garbage = bytes([0x55 & 0x7F] * n_garbage)  # bit7 clear: orphan seconds
    noisy = raw[:cut] + garbage + raw[cut:]
    dids, dvals, dmarks, consumed = protocol.decode_packets(noisy)
    np.testing.assert_array_equal(dids, ids)
    np.testing.assert_array_equal(dvals, vals)
    np.testing.assert_array_equal(dmarks, marks)
    # garbage *between* packets is consumed with them; garbage trailing the
    # last packet may be held back — but retrying the residual (as the host
    # receiver does) must drain it without fabricating packets
    assert consumed >= len(noisy) - n_garbage
    rest_ids, _, _, rest_consumed = protocol.decode_packets(noisy[consumed:])
    assert len(rest_ids) == 0
    assert rest_consumed == len(noisy) - consumed


def _is_subsequence(needle, haystack):
    it = iter(haystack)
    return all(x in it for x in needle)


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.integers(0, 15), st.integers(0, 255))
def test_arbitrary_garbage_never_destroys_real_packets(frames, pos_seed, byte):
    """A single arbitrary garbage byte may fabricate at most one bogus
    packet but every real packet survives (resync on the flag bits)."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    cut = 2 * (pos_seed % (len(ids) + 1))
    noisy = raw[:cut] + bytes([byte]) + raw[cut:]
    dids, dvals, dmarks, _ = protocol.decode_packets(noisy)
    real = list(zip(ids.tolist(), vals.tolist(), marks.tolist()))
    got = list(zip(dids.tolist(), dvals.tolist(), dmarks.tolist()))
    assert _is_subsequence(real, got)
    assert len(got) <= len(real) + 1


@settings(max_examples=100, deadline=None)
@given(FRAMES, st.lists(st.integers(1, 7), min_size=1, max_size=8))
def test_split_across_reads_reassembles_exactly(frames, chunk_sizes):
    """Chunked reads with arbitrary (odd!) split points reassemble through
    the residual-buffer discipline the host receiver uses."""
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    # carve the byte stream into chunks, cycling the given sizes
    chunks = []
    i = k = 0
    while i < len(raw):
        n = chunk_sizes[k % len(chunk_sizes)]
        chunks.append(raw[i : i + n])
        i += n
        k += 1
    residual = b""
    out_ids, out_vals, out_marks = [], [], []
    for chunk in chunks:
        buf = residual + chunk
        dids, dvals, dmarks, consumed = protocol.decode_packets(buf)
        residual = buf[consumed:]
        out_ids.extend(dids.tolist())
        out_vals.extend(dvals.tolist())
        out_marks.extend(dmarks.tolist())
    assert residual == b""
    np.testing.assert_array_equal(out_ids, ids)
    np.testing.assert_array_equal(out_vals, vals)
    np.testing.assert_array_equal(out_marks, marks)


@settings(max_examples=50, deadline=None)
@given(FRAMES)
def test_trailing_first_byte_left_unconsumed(frames):
    ids, vals, marks = _flatten(frames)
    raw = protocol.encode_packets(ids, vals, marks)
    truncated = raw[:-1]  # drop the final second-byte
    dids, _, _, consumed = protocol.decode_packets(truncated)
    assert consumed == len(raw) - 2  # the dangling first byte is kept back
    assert len(dids) == len(ids) - 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 127), min_size=0, max_size=32))
def test_pure_orphan_stream_decodes_nothing(seconds):
    """A stream of nothing but second-bytes consumes fully, yields nothing."""
    buf = bytes(seconds)
    dids, dvals, dmarks, consumed = protocol.decode_packets(buf)
    assert len(dids) == 0
    assert consumed == len(buf)
