"""Per-architecture smoke tests: reduced config, one train step + decode.

Asserts output shapes, finiteness (no NaNs), and prefill/decode parity
(decoding token t+1 from a prefix must match the full-sequence forward).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RunConfig, smoke_config
from repro.models import build_model

RUN = RunConfig(attn_impl="full", remat="none", lr_chunk=8, moe_group=64)
# parity/equivalence checks run in f32: they test correctness, not precision
RUN_F32 = RunConfig(
    attn_impl="full", remat="none", lr_chunk=8, moe_group=64,
    compute_dtype="float32", decode_cache_dtype="float32",
)
B, S = 2, 16


def _batch(cfg, key):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(kf, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    # random init: loss ≈ ln(vocab_padded); generous sanity band
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3.0 * np.log(cfg.vocab_padded)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_grad_step_finite(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    def loss_of(p):
        return model.loss_fn(p, batch)[0]

    grads = jax.jit(jax.grad(loss_of))(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g))) for g in flat)
    # at least the embedding gradient must be nonzero
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_parity(arch):
    """decode_step(prefix) logits == full forward logits at that position."""
    from dataclasses import replace

    cfg = smoke_config(arch)
    if cfg.family == "moe":
        # capacity drops are routing-history dependent; parity needs none
        cfg = replace(cfg, capacity_factor=float(cfg.n_experts))
    model = build_model(cfg, RUN_F32)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    if cfg.is_encdec:
        frames = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        logits_a, cache = model.prefill(params, {"frames": frames, "tokens": tokens[:, :-1]},
                                        max_len=S + 4)
        logits_b, cache = model.decode_step(params, cache, tokens[:, -1])
        # oracle: prefill over the full sequence
        logits_full, _ = model.prefill(params, {"frames": frames, "tokens": tokens},
                                       max_len=S + 4)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        logits_a, cache = model.prefill(params, tokens[:, :-1], max_len=S + 4)
        logits_b, cache = model.decode_step(params, cache, tokens[:, -1])
        logits_full, _ = model.prefill(params, tokens, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_b), np.asarray(logits_full), rtol=1e-3, atol=1e-4
    )
    assert int(cache["pos"]) == S


@pytest.mark.parametrize("arch", ["qwen25_3b", "zamba2_7b", "rwkv6_3b", "phi35_moe"])
def test_multi_token_decode(arch):
    """Greedy-decode 4 tokens; logits stay finite and cache advances."""
    cfg = smoke_config(arch)
    model = build_model(cfg, RUN)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, 8), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, tokens, max_len=16)
    step = jax.jit(model.decode_step)
    for i in range(4):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
        logits, cache = step(params, cache, tok)
        assert np.all(np.isfinite(np.asarray(logits)))
    assert int(cache["pos"]) == 12


def test_scan_vs_unrolled_identical():
    """scan_layers=False (cost lowering) must be numerically identical."""
    cfg = smoke_config("qwen25_3b")
    from dataclasses import replace

    m_scan = build_model(cfg, RUN_F32)
    m_unroll = build_model(cfg, replace(RUN_F32, scan_layers=False))
    params = m_scan.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = jax.jit(m_scan.loss_fn)(params, batch)
    l2, _ = jax.jit(m_unroll.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_chunked_attention_matches_full_in_model():
    from dataclasses import replace

    cfg = smoke_config("granite_20b")
    m_full = build_model(cfg, RUN)
    m_chunk = build_model(cfg, replace(RUN, attn_impl="chunked", q_chunk=8, kv_chunk=8))
    params = m_full.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = jax.jit(m_full.loss_fn)(params, batch)
    l2, _ = jax.jit(m_chunk.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-3)


def test_moe_sort_matches_einsum_when_no_drops():
    """With generous capacity both dispatch impls route identically."""
    from dataclasses import replace

    cfg = smoke_config("phi35_moe")
    cfg = replace(cfg, capacity_factor=4.0)
    m_e = build_model(cfg, replace(RUN, moe_impl="einsum", moe_group=32))
    m_s = build_model(cfg, replace(RUN, moe_impl="sort", moe_group=32))
    params = m_e.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    l1, _ = jax.jit(m_e.loss_fn)(params, batch)
    l2, _ = jax.jit(m_s.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-3)
