"""Flight-recorder gates: overhead, watchdog efficacy, trace round-trip.

Three regression gates over the :mod:`repro.obs` subsystem (nonzero exit
on any failure):

1. **overhead** — the receiver decode hot path with a live
   `TraceRecorder` + `MetricsRegistry` installed must stay within
   ``OVERHEAD_LIMIT`` (3%) of the uninstrumented figure.  Alternating
   enabled/disabled reps, median per mode, so scheduler noise cancels.

2. **watchdog** — a two-device fleet plays a repeating serve step
   (gap/A/gap/B/gap/C) and one device runs a *single* occurrence of
   kernel B at 1.5x power for 8 ms.  The `SignatureWatchdog` (20 kHz
   shape matching) must flag it, flag *nothing* on the clean device, and
   the `PartTimeSampler` negative baseline (10 Hz instantaneous reads,
   the PAPERS.md "part-time power measurement" model) must miss it — the
   excursion lands between its samples by construction.

3. **roundtrip** — the recorded ``serve-churn`` golden replays through a
   `ReplayFleet` with tracing enabled; marker-delimited attribution
   intervals become device-clock spans, and the exported Chrome trace
   JSON must round-trip with every span mapped onto the wall timeline
   (anchored, not parked in the ``device-time`` fallback process) and
   overlapping the receiver counter track.  ``--trace-out`` keeps the
   JSON (CI uploads it as a Perfetto-loadable artifact).

    PYTHONPATH=src python -m benchmarks.obs_overhead [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

import numpy as np

from repro import obs
from repro.core import protocol
from repro.obs import export as obs_export
from repro.obs import trace as obs_trace

from .common import BenchReport, add_json_arg, timer
from .receiver_throughput import _record_stream

#: gate 1: tracing-enabled receiver decode within this factor of disabled
OVERHEAD_LIMIT = 1.03

#: gate 2 workload: one serve step = gap/A/gap/B/gap/C (name, seconds, watts).
#: The 40 W floor keeps relative sensor noise small enough that the
#: normalised-shape distance stays meaningful on the idle segments.
STEP_PATTERN = [
    ("gap", 4e-3, 40.0),
    ("A", 6e-3, 80.0),
    ("gap", 4e-3, 40.0),
    ("B", 8e-3, 150.0),
    ("gap", 4e-3, 40.0),
    ("C", 6e-3, 110.0),
]
STEP_S = sum(d for _, d, _ in STEP_PATTERN)  # 32 ms
N_STEPS = 40
WARM_STEPS = 8  # library is built from the clean device's first 8 steps
TAMPER_STEP = 25  # B at 1.5x in [0.814 s, 0.822 s): between 10 Hz samples
TAMPER_FACTOR = 1.5
SAMPLER_HZ = 10.0

GOLDEN = Path(__file__).resolve().parent.parent / "tests" / "goldens"


# --------------------------------------------------------------- gate 1
def _batch_floor(ps, chunks, reps: int) -> float:
    """Clean per-batch cost of the untraced receiver path.

    Every chunk is an identical 0.05 s poll batch, so each is an
    independent timing sample of the same workload; the minimum over
    all of them is the cost of the code path itself — preemption and
    allocator stalls only ever inflate a sample, never deflate it.
    """
    best = float("inf")
    for _ in range(reps):
        residual = b""
        for chunk in chunks:
            buf = residual + chunk
            with timer() as t:
                ids, vals, marks, consumed = protocol.decode_packets(buf)
                residual = buf[consumed:]
                ps._process(ids, vals, marks)
            best = min(best, t.dt)
    return best


def _instr_cost(ps, rec, n: int = 20_000) -> float:
    """Clean cost of the per-batch instrumentation block.

    This is exactly what `PowerSensor._process` adds per poll batch when
    a recorder is installed (worst case: markers present, so both
    counters fire).  A tight min-of-N loop resolves the ~µs cost far
    more reliably than differencing two ~100 µs populations.
    """
    obs_trace.install(rec)
    best = float("inf")
    for _ in range(n):
        with timer() as t:
            r = obs_trace.active()
            if r is not None:
                track = f"rx:{getattr(ps, 'obs_name', 'dev')}"
                r.anchor_once(1.234)
                r.counter("rx.frames", 1000.0, track=track)
                r.counter("rx.markers", 2.0, track=track)
        best = min(best, t.dt)
    obs_trace.uninstall()
    return best


def gate_overhead(report: BenchReport, seconds: float, reps: int) -> None:
    chunk_s = 0.05
    ps, chunks = _record_stream(seconds, chunk_s=chunk_s)
    rec, _reg = obs.enable()
    obs_trace.uninstall()
    _batch_floor(ps, chunks, 1)  # warm-up: page in the stream
    t_batch = _batch_floor(ps, chunks, reps)
    t_instr = _instr_cost(ps, rec)
    obs.disable()
    # The instrumented block is strictly additive (guarded by a single
    # `if rec is not None`), so enabled-time <= disabled-time + block
    # cost *exactly* — the bound below IS the throughput ratio, built
    # from two stable minima instead of the difference of two noisy
    # ~100 µs populations (which this gate must not flake on).
    ratio = (t_batch + t_instr) / t_batch
    frames = int(chunk_s * 20_000)  # per-batch, all batches equal-sized
    report.emit("obs_receiver_disabled", t_batch / frames * 1e6,
                f"{frames / t_batch:.0f} frames/s")
    report.emit("obs_instr_per_batch_us", t_instr * 1e6,
                "per-poll-batch recorder cost, markers present")
    report.emit("obs_receiver_overhead_pct", (ratio - 1.0) * 100.0,
                f"instrumentation bound over {reps} passes")
    report.gate(
        "overhead", ratio <= OVERHEAD_LIMIT, value=ratio, limit=OVERHEAD_LIMIT,
        detail="tracing-enabled receiver batch time bound / disabled",
    )


# --------------------------------------------------------------- gate 2
def _pattern_arrays(n_steps: int, tamper_step: int | None = None):
    """Piecewise-constant (times, watts) for `TraceLoad` playback."""
    eps = 1e-6
    ts = [0.0]
    ws = [STEP_PATTERN[0][2]]
    t = 0.0
    for k in range(n_steps):
        for name, dur, w in STEP_PATTERN:
            if k == tamper_step and name == "B":
                w *= TAMPER_FACTOR
            ts += [t + eps, t + dur]
            ws += [w, w]
            t += dur
    return np.asarray(ts), np.asarray(ws)


def gate_watchdog(report: BenchReport) -> None:
    from repro.attrib.attribute import KernelSpan
    from repro.attrib.signatures import build_library
    from repro.core.dut import TraceLoad
    from repro.obs.watch import PartTimeSampler, SignatureWatchdog
    from repro.stream.fleet import make_virtual_fleet

    clean_t, clean_w = _pattern_arrays(N_STEPS)
    tamp_t, tamp_w = _pattern_arrays(N_STEPS, tamper_step=TAMPER_STEP)
    fleet = make_virtual_fleet(
        [
            TraceLoad(times_s=clean_t, watts=clean_w),
            TraceLoad(times_s=tamp_t, watts=tamp_w),
        ],
        ring_capacity=1 << 16,
    )
    try:
        warm_s = WARM_STEPS * STEP_S
        fleet.advance(warm_s)

        # library from the clean device's measured ring; span offsets are
        # analytic because TraceLoad playback starts at device t = 0
        block = fleet["dev0"].ring.window(0.0, warm_s)
        spans = []
        for k in range(WARM_STEPS):
            t = k * STEP_S
            for name, dur, _ in STEP_PATTERN:
                spans.append(KernelSpan(name, t, t + dur))
                t += dur
        lib = build_library(block.times_s, block.total_watts, spans)

        dog = SignatureWatchdog(fleet, lib)
        dog.check()  # attach cursors at warm_s
        tamper_read = lambda t: float(np.interp(t, tamp_t, tamp_w))  # noqa: E731
        sampler = PartTimeSampler(tamper_read, rate_hz=SAMPLER_HZ)

        total_s = N_STEPS * STEP_S
        now = warm_s
        while now < total_s - 1e-9:
            step = min(2 * STEP_S, total_s - now)
            fleet.advance(step)
            now += step
            sampler.poll(now)
            dog.check()
    finally:
        fleet.close()

    t0_tamp = TAMPER_STEP * STEP_S + sum(
        d for n, d, _ in STEP_PATTERN[: next(
            i for i, (n, _, _) in enumerate(STEP_PATTERN) if n == "B")]
    )
    t1_tamp = t0_tamp + dict((n, d) for n, d, _ in STEP_PATTERN)["B"]

    clean_anoms = [a for a in dog.anomalies if a.device == "dev0"]
    dev1_anoms = [a for a in dog.anomalies if a.device == "dev1"]
    hits = [a for a in dev1_anoms if a.t0_s < t1_tamp and a.t1_s > t0_tamp]
    honest_peak = max(w for _, _, w in STEP_PATTERN)
    band_hi = honest_peak * 1.1  # generous band around the honest workload
    sampler_hits = sampler.detect(0.0, band_hi)

    report.record("obs_watchdog_segments", dog.n_segments, "segments judged")
    report.record("obs_watchdog_anomalies", len(dog.anomalies))
    report.record("obs_sampler_samples", len(sampler.samples),
                  f"{SAMPLER_HZ:.0f} Hz part-time reads")
    report.gate(
        "watchdog_flags_tamper", len(hits) >= 1, value=float(len(hits)),
        limit=1.0,
        detail=f"anomalies overlapping the 1.5x B window "
               f"[{t0_tamp:.3f}, {t1_tamp:.3f}) s",
    )
    report.gate(
        "watchdog_clean_quiet", not clean_anoms, value=float(len(clean_anoms)),
        limit=0.0, detail="false positives on the untampered device",
    )
    report.gate(
        "watchdog_no_stray_flags", len(dev1_anoms) == len(hits),
        value=float(len(dev1_anoms) - len(hits)), limit=0.0,
        detail="tampered-device anomalies outside the injected window",
    )
    report.gate(
        "sampler_misses_tamper", not sampler_hits,
        value=float(len(sampler_hits)), limit=0.0,
        detail=f"{SAMPLER_HZ:.0f} Hz band detector hits (an 8 ms excursion "
               "must land between its samples)",
    )
    if hits:
        a = hits[0]
        print(f"# watchdog: {a.kind} on {a.device}: {a.name} at "
              f"[{a.t0_s:.3f}, {a.t1_s:.3f}) s, {a.mean_w:.0f} W "
              f"(expected {a.expected_w or float('nan'):.0f} W); "
              f"{SAMPLER_HZ:.0f} Hz sampler took {len(sampler.samples)} "
              f"samples and saw nothing over {band_hi:.0f} W")


# --------------------------------------------------------------- gate 3
def gate_roundtrip(report: BenchReport, trace_out: str | None) -> None:
    from repro.replay import ReplayFleet

    obs.disable()
    rec, _reg = obs.enable()
    fleet = ReplayFleet.from_file(GOLDEN / "serve-churn.npz")
    try:
        frames = fleet.drain()
        n_spans = 0
        session_s = 0.0
        for name in fleet.names:
            ps = fleet[name]
            marks = [t for ch, t in ps.markers if ch == "I"]
            for k in range(1, len(marks)):
                rec.device_span(f"int{k}", marks[k - 1], marks[k],
                                track=f"attr:{name}")
                n_spans += 1
            if len(ps.ring):
                all_t = ps.ring.window(0.0, ps.ring.last_time_s + 1.0).times_s
                session_s = max(session_s, float(all_t[-1] - all_t[0]))
    finally:
        fleet.close()

    text = obs_export.chrome_trace_json(rec, metadata={"scenario": "serve-churn"})
    if trace_out:
        with open(trace_out, "w") as fh:
            fh.write(text)
        print(f"# wrote Perfetto trace to {trace_out}")
    obs.disable()

    doc = json.loads(text)  # the round-trip itself
    evs = doc["traceEvents"]
    attr = [e for e in evs if e.get("ph") == "X"
            and e.get("name", "").startswith("int")]
    counters = [e for e in evs if e.get("ph") == "C"
                and e.get("name") == "rx.frames"]
    report.record("obs_roundtrip_frames", frames, "golden frames replayed")
    report.record("obs_roundtrip_spans", n_spans, "attribution intervals")
    report.record("obs_roundtrip_events", len(evs), "chrome trace events")

    report.gate(
        "roundtrip_spans_present", len(attr) == n_spans and n_spans > 0,
        value=float(len(attr)), limit=float(n_spans),
        detail="attribution spans surviving export -> JSON -> parse",
    )
    aligned = bool(attr) and all(e["pid"] == 1 for e in attr)
    report.gate(
        "roundtrip_spans_anchored", aligned,
        detail="device-clock spans mapped onto the wall timeline "
               "(no device-time fallback process)",
    )
    frame_total = sum(e["args"]["rx.frames"] for e in counters)
    report.gate(
        "roundtrip_counters_conserve", counters and frame_total == frames,
        value=float(frame_total), limit=float(frames),
        detail="rx.frames counter total equals frames replayed",
    )
    # Max-speed replay compresses the whole device session into the drain
    # window, and the anchor pins its *end* there — so the attribution
    # track must sit within one session-length behind the counter track,
    # never ahead of it and never off on its own timeline.
    slack_us = 2000.0
    session_us = session_s * 1e6
    if attr and counters:
        a_lo = min(e["ts"] for e in attr)
        a_hi = max(e["ts"] + e["dur"] for e in attr)
        c_lo = min(e["ts"] for e in counters)
        c_hi = max(e["ts"] for e in counters)
        aligned_window = (a_hi <= c_hi + slack_us
                          and a_lo >= c_lo - session_us - slack_us)
    else:
        aligned_window = False
    report.gate(
        "roundtrip_tracks_aligned", aligned_window,
        detail="attribution spans land within one session-length of the "
               "receiver counter track on the shared wall timeline",
    )


def run(seconds: float, reps: int, trace_out: str | None,
        json_path: str | None = None) -> int:
    report = BenchReport("obs_overhead", {"seconds": seconds, "reps": reps})
    try:
        gate_overhead(report, seconds, reps)
        gate_watchdog(report)
        gate_roundtrip(report, trace_out)
    finally:
        obs.disable()
    ok = report.finish(json_path=json_path)
    for g in report.gates:
        mark = "ok" if g["passed"] else "FAIL"
        lim = "" if g["value"] is None else (
            f" ({g['value']:.4g} vs limit {g['limit']:.4g})")
        print(f"{mark}: {g['name']}{lim} — {g['detail']}")
    return 0 if ok else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--seconds", type=float, default=None,
                    help="overhead-gate stream length")
    ap.add_argument("--reps", type=int, default=None,
                    help="alternating enabled/disabled reps")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="keep the round-trip Perfetto trace JSON")
    add_json_arg(ap)
    args = ap.parse_args(argv)
    seconds = args.seconds if args.seconds is not None else (
        2.0 if args.smoke else 4.0)
    reps = args.reps if args.reps is not None else (5 if args.smoke else 7)
    return run(seconds, reps, args.trace_out, json_path=args.json)


if __name__ == "__main__":
    sys.exit(main())
