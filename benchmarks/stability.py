"""Paper §IV-B: long-term stability — 50 h, 7.5 A, 128 k samples / 15 min.

Simulated-time fast-forward (the virtual clock makes 50 h free); reports
the fluctuation of the per-window average power (paper: ±0.09 W).
"""
from __future__ import annotations

import numpy as np

from repro.core import ConstantLoad, Joules, PowerSensor, Watt, make_device
from repro.core.calibration import calibrate

from .common import emit, timer


def run(hours: float = 50.0, windows: int = 50, samples: int = 16_000) -> None:
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 0.0), seed=6)
    ps = PowerSensor(dev)
    calibrate(ps, {0: 12.0}, n_samples=8000)
    dev.firmware.dut.loads[0] = ConstantLoad(12.0, 7.5)
    gap_s = hours * 3600.0 / windows
    means = []
    with timer() as t:
        for _ in range(windows):
            # fast-forward the idle gap without streaming cost
            ps.stop_streaming()
            dev.advance(gap_s - samples / 20_000.0)
            ps.start_streaming()
            a = ps.read()
            ps.run_for(samples / 20_000.0)
            b = ps.read()
            means.append(Watt(a, b))
    means = np.array(means)
    fluct = np.ptp(means) / 2
    emit(
        "stability/50h",
        t.us / windows,
        f"windows={windows} mean={means.mean():.3f}W fluct=±{fluct:.3f}W "
        f"paper=±0.09W no_recalibration=True",
    )
