"""Receiver hot-path throughput: decoded frames/s, seed vs vectorised.

The seed receiver fell back to per-frame Python loops for dump output and
per-channel boolean masking for conversion — exactly the host-overhead
trap the paper's §III-C lightweight-thread design avoids.  This benchmark
replays the *same* pre-generated 10 s, 8-channel, dump-enabled byte stream
through

* ``legacy``     — a faithful copy of the seed `_process` hot path
  (per-sid masked `raw_to_physical`, nested f-string dump loop);
* ``vectorised`` — the current `PowerSensor` receiver (fused affine
  conversion, ring-buffer append, batched %-format dump).

``--replay`` additionally records the vectorised session into a
`repro.replay` trace archive and replays it at max speed through a fresh
receiver, gating that replay sustains **at least the live decoded
frames/s figure** — the archive path must never become the slow way to
consume a session.  (Replay carries no dump sink, so it has headroom
over the dump-enabled live figure by construction; losing the gate means
the replay transport itself regressed.)

    PYTHONPATH=src python -m benchmarks.receiver_throughput [seconds] [--smoke] [--replay]
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.core import protocol
from repro.core.firmware import FRAME_US, N_CHANNELS
from repro.core.host import MAX_PAIRS

from .common import BenchReport, add_json_arg, timer


class _NullDump:
    """Counts dumped characters without retaining them."""

    def __init__(self):
        self.chars = 0

    def write(self, s: str) -> None:
        self.chars += len(s)

    def flush(self) -> None: ...

    def tell(self) -> int:
        return self.chars


def _record_stream(seconds: float, chunk_s: float = 0.5):
    """Generate the 8-channel 20 kHz byte stream once, in poll-sized chunks."""
    dev = make_device(
        ["pcie8pin-20a", "slot-10a-12v", "slot-10a-3v3", "hc-50a"],
        ConstantLoad(12.0, 4.0),
        seed=0,
    )
    # ring sized to retain the whole run: --replay archives it afterwards
    capacity = 1 << max(int(seconds * 20_000 + 1024) - 1, 1).bit_length()
    ps = PowerSensor(dev, ring_capacity=capacity)  # handshake; stream starts
    chunks = []
    remaining = seconds
    while remaining > 1e-12:
        step = min(chunk_s, remaining)
        dev.advance(step)
        chunks.append(dev.read())
        remaining -= step
    return ps, chunks


class LegacyReceiver:
    """The seed _process hot path, verbatim (for before/after comparison)."""

    def __init__(self, configs, dump):
        self.configs = configs
        self._dump = dump
        self._dump_every = 1
        self._last_ts10 = None
        self._device_time_us = 0.0
        self._energy = np.zeros(MAX_PAIRS)
        self._n_samples = 0

    def process(self, ids, vals, marks) -> int:
        is_ts = protocol.is_timestamp(ids, marks)
        ts_idx = np.flatnonzero(is_ts)
        if ts_idx.size == 0:
            return 0
        ts_vals = vals[ts_idx]
        if self._last_ts10 is None:
            base = float(ts_vals[0])
            self._device_time_us = base
            deltas = np.diff(ts_vals) % 1024
            times = base + np.concatenate([[0], np.cumsum(deltas)])
        else:
            d0 = (ts_vals[0] - self._last_ts10) % 1024
            deltas = np.concatenate([[d0], np.diff(ts_vals) % 1024])
            times = self._device_time_us + np.cumsum(deltas)
        self._last_ts10 = int(ts_vals[-1])
        self._device_time_us = float(times[-1])

        n_frames = ts_idx.size
        dt_s = FRAME_US / 1e6
        data_mask = ~is_ts
        d_ids = ids[data_mask]
        d_vals = vals[data_mask]
        frame_of = np.searchsorted(ts_idx, np.flatnonzero(data_mask)) - 1
        ok = frame_of >= 0
        d_ids, d_vals, frame_of = d_ids[ok], d_vals[ok], frame_of[ok]

        volts = np.zeros((n_frames, MAX_PAIRS))
        amps = np.zeros((n_frames, MAX_PAIRS))
        for sid in range(N_CHANNELS):
            blk = self.configs[sid]
            if not blk.enabled:
                continue
            sel = d_ids == sid
            if not np.any(sel):
                continue
            phys = blk.raw_to_physical(d_vals[sel])
            tgt = amps if blk.type_code == 0 else volts
            tgt[frame_of[sel], sid // 2] = phys

        watts = volts * amps
        self._energy += watts.sum(axis=0) * dt_s
        self._n_samples += n_frames

        step = self._dump_every
        sel = np.arange(0, n_frames, step)
        lines = []
        for f in sel:
            t = times[f] / 1e6
            for p in range(MAX_PAIRS):
                if self.configs[2 * p].enabled:
                    lines.append(
                        f"{t:.6f} {p} {volts[f, p]:.4f} {amps[f, p]:.4f} {watts[f, p]:.4f}\n"
                    )
        self._dump.write("".join(lines))
        return n_frames


def _run_legacy(ps, chunks) -> tuple[float, int, float]:
    dump = _NullDump()
    rx = LegacyReceiver(ps.configs, dump)
    frames = 0
    residual = b""
    with timer() as t:
        for chunk in chunks:
            buf = residual + chunk
            ids, vals, marks, consumed = protocol.decode_packets(buf)
            residual = buf[consumed:]
            frames += rx.process(ids, vals, marks)
    return t.dt, frames, float(rx._energy.sum())


def _run_vectorised(ps, chunks) -> tuple[float, int, float]:
    dump = _NullDump()
    ps.set_dump_file(dump)
    frames = 0
    residual = b""
    with timer() as t:
        for chunk in chunks:
            buf = residual + chunk
            ids, vals, marks, consumed = protocol.decode_packets(buf)
            residual = buf[consumed:]
            frames += ps._process(ids, vals, marks)
    ps.set_dump_file(None)
    return t.dt, frames, float(ps._energy.sum())


def _run_replay(ps, frames_per_poll: int = 10_000) -> tuple[float, int, float]:
    """Archive the live session, then max-speed replay through a fresh
    receiver.  Chunks are pre-encoded (`preload`) so the timed region is
    the receiver path alone — decode, frame assembly, conversion, ring —
    exactly what the live figure times."""
    from repro.replay import SessionRecorder, replay_sensor

    rec = SessionRecorder(ps, include_history=True)
    rec.capture()
    trace = rec.finalize().devices["dev0"]
    rps = replay_sensor(trace, chunk_frames=frames_per_poll)
    rps.device.preload()
    frames = 0
    with timer() as t:
        while not rps.device.exhausted:
            frames += rps.poll()
    energy = float(rps._energy.sum())
    return t.dt, frames, energy


def run(seconds: float = 10.0, replay: bool = False, json_path: str | None = None) -> int:
    report = BenchReport("receiver_throughput",
                         {"seconds": seconds, "replay": replay})
    ps, chunks = _record_stream(seconds)
    stream_bytes = sum(len(c) for c in chunks)
    dt_new, frames_new, e_new = _run_vectorised(ps, chunks)
    dt_old, frames_old, e_old = _run_legacy(ps, chunks)
    assert frames_new == frames_old, (frames_new, frames_old)
    assert abs(e_new - e_old) < max(1e-6, 1e-6 * abs(e_old)), (e_new, e_old)
    fps_old = frames_old / dt_old
    fps_new = frames_new / dt_new
    report.emit("receiver_legacy", dt_old / frames_old * 1e6, f"{fps_old:.0f} frames/s")
    report.emit("receiver_vectorised", dt_new / frames_new * 1e6, f"{fps_new:.0f} frames/s")
    print(
        f"# {frames_new} frames ({stream_bytes/1e6:.1f} MB stream, "
        f"{seconds:.0f} s at 20 kHz, 8 ch, dump on): "
        f"legacy {fps_old:,.0f} -> vectorised {fps_new:,.0f} frames/s "
        f"({fps_new/fps_old:.1f}x)"
    )
    if not replay:
        report.finish(json_path=json_path)
        return 0
    dt_rep, frames_rep, e_rep = _run_replay(ps)
    assert frames_rep == frames_new, (frames_rep, frames_new)
    assert abs(e_rep - e_new) <= 1e-9 * abs(e_new), (e_rep, e_new)
    fps_rep = frames_rep / dt_rep
    report.emit("receiver_replay", dt_rep / frames_rep * 1e6, f"{fps_rep:.0f} frames/s")
    print(
        f"# replay: {fps_rep:,.0f} frames/s through the real receiver "
        f"({fps_rep/fps_new:.2f}x the live figure)"
    )
    ok = report.gate("replay_not_slower", fps_rep >= fps_new,
                     value=fps_rep / fps_new, limit=1.0,
                     detail="max-speed archive replay >= live decoded frames/s")
    if not ok:
        print(
            f"FAIL: max-speed replay ({fps_rep:,.0f} frames/s) is slower than "
            f"the live receiver ({fps_new:,.0f} frames/s) — replay must not "
            f"become the slow path"
        )
    report.finish(json_path=json_path)
    return 0 if ok else 1


if __name__ == "__main__":
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("seconds", nargs="?", type=float, default=10.0)
    ap.add_argument("--smoke", action="store_true", help="CI-sized run (1 s)")
    ap.add_argument("--replay", action="store_true",
                    help="gate max-speed archive replay >= the live figure")
    add_json_arg(ap)
    args = ap.parse_args()
    sys.exit(run(1.0 if args.smoke else args.seconds, replay=args.replay,
                 json_path=args.json))
