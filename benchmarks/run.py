"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1 fig8
"""
from __future__ import annotations

import sys
import traceback

from . import (
    fig4_sweep,
    fig5_step,
    fig7_compare,
    fig8_tuning,
    fig12_storage,
    receiver_throughput,
    roofline_report,
    stability,
    table1_accuracy,
    table2_sampling,
)

BENCHES = {
    "table1": table1_accuracy.run,
    "table2": table2_sampling.run,
    "fig4": fig4_sweep.run,
    "fig5": fig5_step.run,
    "fig7": fig7_compare.run,
    "fig8": fig8_tuning.run,
    "fig12": fig12_storage.run,
    "stability": stability.run,
    "roofline": roofline_report.run,
    "receiver": receiver_throughput.run,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    failed = []
    for name in names:
        try:
            BENCHES[name]()
        except Exception:
            traceback.print_exc()
            failed.append(name)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
