"""Paper Fig 4: power error across a −10 A → +10 A load sweep.

Per step: 128 k samples (paper protocol), reporting mean/min/max error;
all errors must sit inside the Table I worst-case envelope.
"""
from __future__ import annotations

import numpy as np

from repro.core import ConstantLoad, Joules, PowerSensor, Watt, make_device
from repro.core.calibration import calibrate
from repro.core.sensors import MODULE_CATALOG

from .common import emit, timer


def run(samples_per_step: int = 16_000) -> None:
    module = "slot-10a-12v"
    spec = MODULE_CATALOG[module]
    dev = make_device([module], ConstantLoad(12.0, 0.0), seed=4)
    ps = PowerSensor(dev)
    calibrate(ps, {0: 12.0}, n_samples=8000)
    worst = 0.0
    with timer() as t:
        for amps in np.arange(-10.0, 10.5, 1.0):
            dev.firmware.dut.loads[0] = ConstantLoad(12.0, float(amps))
            a = ps.read()
            ps.run_for(samples_per_step / 20_000.0)
            b = ps.read()
            err = Watt(a, b) - 12.0 * amps
            worst = max(worst, abs(err))
    emit(
        "fig4/sweep",
        t.us / 21,
        f"21 steps, worst|err|={worst:.3f}W envelope=±{spec.power_error:.2f}W "
        f"inside={worst < spec.power_error}",
    )
