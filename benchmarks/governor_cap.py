"""Power-cap governor adherence vs telemetry rate (closed-loop Fig 5).

The paper's speed claim, applied to *control* instead of observation: a
PI power-cap governor actuating modelled DVFS states × decode batch over
a virtual sensor fleet

* holds a fleet-level cap with **time-over-cap < 5 %** and **settles
  < 100 ms** after a load step when fed 20 kHz windowed telemetry from
  the ring buffers (`FleetMonitor.window_power_w`);
* demonstrably fails when the identical controller is fed builtin-rate
  sample-and-hold readings (10 Hz, the nvidia-smi regime of
  arXiv:2312.02741): the load step goes unseen for up to a full sample
  period, then stale-error windup swings the plant between over-cap and
  idle.

Adherence is scored against the plant's ground-truth actuation log (the
sensors are calibrated first, §III-D), with the tolerance band equal to
the governor's own 2 % hysteresis.  Exits nonzero when the 20 kHz loop
misses its targets or the 10 Hz loop *stops failing* (both mean the
model drifted), so CI runs ``--smoke`` as a regression gate.

``--chaos`` runs the conformance smoke instead: one device's transport
disconnects and reconnects mid-run (`repro.faultlab`).  Gates: the fleet
cap holds through the cycle (time-over-cap < 5 % on quorum-rescaled
telemetry) and the fleet is healthy again within 200 ms of reconnect.

    PYTHONPATH=src python -m benchmarks.governor_cap [--smoke] [--chaos]
"""
from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from repro.sched import (
    GovernorConfig,
    OperatingGrid,
    PowerCapGovernor,
    SampledPowerReader,
    VirtualPlant,
    decode_cost_of_batch,
    settle_time,
    time_over_cap,
)

from .common import BenchReport, add_json_arg

TOC_LIMIT = 0.05  # max acceptable fraction of time over cap (20 kHz)
SETTLE_LIMIT_S = 0.100  # max acceptable settle after a load step (20 kHz)
BAND_TOL = 0.02  # adherence band = cap · (1 + tol), the governor's own band

#: synthetic serving arch: 40 M params, 4 layers, 8-token chunked decode
N_PARAMS = 40e6
N_LAYERS = 4
CHUNK = 8
MAX_BATCH = 32


def build_grid() -> OperatingGrid:
    cost = decode_cost_of_batch(
        2.0 * N_PARAMS, 2.0 * N_PARAMS, tokens_per_slot_step=CHUNK
    )
    return OperatingGrid(
        cost, n_layers=N_LAYERS, batches=(1, 2, 4, 8, 16, 32),
        tokens_per_slot_step=CHUNK,
    )


def run_loop(
    grid: OperatingGrid,
    n_devices: int,
    cap_w: float,
    duration_s: float,
    t_step_s: float,
    seed: int,
    rate_hz: float | None,
):
    """One closed-loop run; returns (toc, settle_s, mean tokens/s, switches)."""
    plant = VirtualPlant(grid, n_devices=n_devices, seed=seed)
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    reader = None
    if rate_hz is not None:
        reader = SampledPowerReader(
            lambda now: plant.fleet.window_power_w(cfg.window_s), rate_hz
        )
    gov = PowerCapGovernor(plant, cfg, read_power=reader)
    gov.run(
        duration_s,
        demand_of_t=lambda t: 0 if t < t_step_s else MAX_BATCH,
    )
    toc = time_over_cap(plant.log, cap_w, 0.0, duration_s, tol=BAND_TOL)
    settle = settle_time(plant.log, cap_w, t_step_s, duration_s, tol=BAND_TOL)
    tps = float(
        np.mean(
            [s.point.tokens_per_s for s in gov.history if s.time_s >= t_step_s]
        )
    )
    switches = gov.n_switches
    plant.close()
    return toc, settle, tps, switches


def run(duration_s: float, seed: int, n_devices: int,
        json_path: str | None = None) -> int:
    report = BenchReport(
        "governor_cap",
        {"duration_s": duration_s, "seed": seed, "devices": n_devices},
    )
    grid = build_grid()
    # cap at ~72 % of the fleet's unconstrained draw: binding but feasible
    cap_w = 0.72 * n_devices * grid.max_watts
    t_step_s = 0.3 * duration_s
    print(f"fleet: {n_devices} devices, cap {cap_w:.0f} W "
          f"(uncapped demand ~{n_devices * grid.max_watts:.0f} W), "
          f"load step at {t_step_s * 1e3:.0f} ms, run {duration_s * 1e3:.0f} ms")

    failures: list[str] = []
    results = {}
    for label, rate in (("20khz", None), ("100hz", 100.0), ("10hz", 10.0)):
        toc, settle, tps, switches = run_loop(
            grid, n_devices, cap_w, duration_s, t_step_s, seed, rate
        )
        results[label] = (toc, settle)
        print(f"== {label}: time-over-cap {toc * 100.0:.1f}%  "
              f"settle {settle * 1e3:.1f} ms  "
              f"throughput {tps / 1e6:.2f} Mtok/s  switches {switches}")
        report.emit(f"governor_{label}_time_over_cap_pct", toc * 100.0,
                    f"cap {cap_w:.0f} W")
        report.emit(f"governor_{label}_settle_ms", settle * 1e3,
                    "after load step")

    toc20, settle20 = results["20khz"]
    if toc20 > TOC_LIMIT:
        failures.append(
            f"20 kHz time-over-cap {toc20:.1%} > {TOC_LIMIT:.0%}")
    if settle20 > SETTLE_LIMIT_S:
        failures.append(
            f"20 kHz settle {settle20 * 1e3:.1f} ms > {SETTLE_LIMIT_S * 1e3:.0f} ms")
    toc10, settle10 = results["10hz"]
    if toc10 <= TOC_LIMIT and settle10 <= SETTLE_LIMIT_S:
        failures.append(
            "10 Hz telemetry unexpectedly held the cap — the closed-loop "
            "granularity experiment no longer discriminates")

    report.gate("toc_20khz", toc20 <= TOC_LIMIT, value=toc20, limit=TOC_LIMIT)
    report.gate("settle_20khz", settle20 <= SETTLE_LIMIT_S,
                value=settle20, limit=SETTLE_LIMIT_S)
    report.gate("builtin_rate_fails", toc10 > TOC_LIMIT or settle10 > SETTLE_LIMIT_S,
                value=toc10, detail="10 Hz loop must demonstrably fail")
    report.finish(failures, json_path)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"OK: 20 kHz governor holds the cap (over-cap {toc20:.1%} < "
          f"{TOC_LIMIT:.0%}, settle {settle20 * 1e3:.0f} ms < "
          f"{SETTLE_LIMIT_S * 1e3:.0f} ms); 10 Hz builtin-rate telemetry "
          f"demonstrably fails (over-cap {toc10:.1%}, settle "
          f"{settle10 * 1e3:.0f} ms)")
    return 0


CHAOS_TOC_LIMIT = 0.05  # max fraction of time over cap through the cycle
CHAOS_RECOVERY_LIMIT_S = 0.200  # max time to reacquire after reconnect


def run_chaos(duration_s: float, seed: int, n_devices: int,
              json_path: str | None = None) -> int:
    """Conformance smoke: disconnect→reconnect one device mid-run.

    The governor runs on quorum-rescaled fleet telemetry
    (`FleetMonitor.fleet_power`); losing one transport must neither blow
    the cap (the survivors' rescaled estimate keeps the loop closed) nor
    stay degraded after the link returns.
    """
    from repro.faultlab import Disconnect, Scenario, inject

    grid = build_grid()
    cap_w = 0.72 * n_devices * grid.max_watts
    t_dc = 0.4 * duration_s
    t_rc = 0.6 * duration_s
    plant = VirtualPlant(grid, n_devices=n_devices, seed=seed)
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    victim = plant.fleet.names[0]
    inject(
        plant.fleet,
        Scenario(faults=(Disconnect(t_dc, t_rc, devices=(victim,)),), seed=seed),
    )
    gov = PowerCapGovernor(plant, cfg)
    print(f"chaos: {n_devices} devices, cap {cap_w:.0f} W, {victim} "
          f"disconnected {t_dc * 1e3:.0f}-{t_rc * 1e3:.0f} ms of "
          f"{duration_s * 1e3:.0f} ms")

    t = 0.0
    t_recovered = None
    degraded_ticks = 0
    while t < duration_s - 1e-12:
        plant.set_demand(MAX_BATCH)
        gov.step(t)
        health = plant.fleet.device_health()
        if not health[victim].healthy and t >= t_dc:
            degraded_ticks += 1
            t_recovered = None
        elif t >= t_rc and t_recovered is None and health[victim].healthy:
            t_recovered = t
        plant.advance(cfg.dt_s)
        t += cfg.dt_s

    toc = time_over_cap(plant.log, cap_w, 0.0, duration_s, tol=BAND_TOL)
    recovery = (t_recovered - t_rc) if t_recovered is not None else math.inf
    stale_ticks = gov.n_stale_ticks
    plant.close()

    print(f"== chaos: time-over-cap {toc * 100.0:.1f}%  "
          f"recovery {recovery * 1e3:.1f} ms  degraded ticks {degraded_ticks}  "
          f"stale ticks {stale_ticks}")
    report = BenchReport(
        "governor_cap_chaos",
        {"duration_s": duration_s, "seed": seed, "devices": n_devices},
    )
    report.emit("governor_chaos_time_over_cap_pct", toc * 100.0,
                f"1-device disconnect, cap {cap_w:.0f} W")
    report.emit("governor_chaos_recovery_ms", recovery * 1e3, "after reconnect")

    failures: list[str] = []
    if toc > CHAOS_TOC_LIMIT:
        failures.append(
            f"time-over-cap {toc:.1%} > {CHAOS_TOC_LIMIT:.0%} through the "
            "disconnect cycle")
    if recovery > CHAOS_RECOVERY_LIMIT_S:
        failures.append(
            f"recovery {recovery * 1e3:.0f} ms > "
            f"{CHAOS_RECOVERY_LIMIT_S * 1e3:.0f} ms after reconnect")
    if degraded_ticks == 0:
        failures.append(
            "the disconnect was never visible in device health — the chaos "
            "experiment no longer degrades anything")
    report.gate("chaos_toc", toc <= CHAOS_TOC_LIMIT,
                value=toc, limit=CHAOS_TOC_LIMIT)
    report.gate("chaos_recovery", recovery <= CHAOS_RECOVERY_LIMIT_S,
                value=recovery, limit=CHAOS_RECOVERY_LIMIT_S)
    report.gate("chaos_degrades", degraded_ticks > 0, value=degraded_ticks)
    report.finish(failures, json_path)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"OK: cap held through disconnect→reconnect (over-cap {toc:.1%} < "
          f"{CHAOS_TOC_LIMIT:.0%}), fleet reacquired in "
          f"{recovery * 1e3:.0f} ms < {CHAOS_RECOVERY_LIMIT_S * 1e3:.0f} ms")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--chaos", action="store_true",
                    help="disconnect/reconnect conformance smoke")
    ap.add_argument("--duration", type=float, default=None,
                    help="simulated seconds per loop")
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    add_json_arg(ap)
    args = ap.parse_args(argv)
    duration = args.duration if args.duration is not None else (
        0.6 if args.smoke else 2.0)
    devices = args.devices if args.devices is not None else (
        2 if args.smoke else 4)
    if args.chaos:
        return run_chaos(duration, args.seed, devices, json_path=args.json)
    return run(duration, args.seed, devices, json_path=args.json)


if __name__ == "__main__":
    sys.exit(main())
