"""Paper Fig 5: step response — 3.3 A ↔ 8 A at 100 Hz, sampled at 20 kHz.

Reports the 10–90 % rise time in *samples*: the paper's point is that the
transition is resolved by a handful of 50 µs samples.
"""
from __future__ import annotations

import io

import numpy as np

from repro.core import PowerSensor, SquareWaveLoad, make_device

from .common import emit, timer


def run() -> None:
    load = SquareWaveLoad(volts=12.0, amps_lo=3.3, amps_hi=8.0, freq_hz=100.0,
                          slew_tau_s=25e-6)
    dev = make_device(["slot-10a-12v"], load, seed=5)
    ps = PowerSensor(dev)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    with timer() as t:
        ps.run_for(0.05)  # 5 periods
    rows = [l.split() for l in buf.getvalue().splitlines() if l and l[0].isdigit()]
    amps = np.array([float(r[3]) for r in rows])
    lo, hi = 3.3, 8.0
    th_lo, th_hi = lo + 0.1 * (hi - lo), lo + 0.9 * (hi - lo)
    # find rising edges and count samples between thresholds
    rises = []
    state = "low"
    start = 0
    for i, a in enumerate(amps):
        if state == "low" and a > th_lo:
            state, start = "rising", i
        elif state == "rising":
            if a > th_hi:
                rises.append(i - start + 1)
                state = "high"
        if state == "high" and a < th_lo:
            state = "low"
    emit(
        "fig5/step_response",
        t.us,
        f"edges={len(rises)} rise_10_90={np.mean(rises):.1f} samples "
        f"({np.mean(rises)*50:.0f}us at 20kHz) modulation=100Hz",
    )
