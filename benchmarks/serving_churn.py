"""Step-granularity vs wave-granularity billing under churn (regression gate).

The scheduling-side version of the paper's granularity argument: per
-request energy accounting is only as good as the attribution window.
This benchmark runs the *same* churn workload (staggered arrivals, mixed
generation lengths, completions freeing slots mid-run) through both
serving granularities and scores each against the per-step ground truth
of its own execution — every step's energy split equally across the
requests actually decoding in it:

* **step** — `ContinuousBatch`: admissions at step-interval boundaries,
  per-request billing from the interval occupancy matrix;
* **wave** — `EnergySloScheduler`: serial waves decoding every member to
  the longest request, billing split by whole-wave token share.

Gates (nonzero exit on regression):

1. mean per-request billing error of step granularity is **strictly
   lower** than wave granularity on the same workload, with margin
   (``step <= STEP_VS_WAVE_MARGIN x wave``);
2. under ``cap-strict`` admission the modelled fleet power stays at or
   under the cap at **every** decode step (zero overshoot steps) while
   the batch churns;
3. the billing ledger conserves: per-request billed joules plus unbilled
   overhead reproduce the settled total exactly.

    PYTHONPATH=src python -m benchmarks.serving_churn [--smoke]
"""
from __future__ import annotations

import argparse
import math
import sys

import numpy as np

from repro.sched import (
    ContinuousBatch,
    EnergyPricer,
    EnergySloScheduler,
    Request,
    get_policy,
)

from .common import BenchReport, add_json_arg

#: gate 1: step billing error must be at most this fraction of wave error
STEP_VS_WAVE_MARGIN = 0.8
#: gate 2: tolerated cap overshoot at any step boundary (modelled watts)
CAP_EPS_W = 1e-9
#: gate 3: billing conservation slack (relative)
CONSERVE_RTOL = 1e-9

POWER = lambda b: 80.0 + 15.0 * b  # noqa: E731 — modelled batch power
STEP_S = 1e-3  # modelled per-step time, constant
BIAS = 1.1  # measured = modelled x bias (exercises the pricer loop)


def make_workload(n_requests: int, n_clients: int, spread_s: float, seed: int):
    """One churn request set, identical for both executors."""
    rng = np.random.default_rng(seed)
    gen_lens = rng.integers(4, 25, size=n_requests)
    clients = rng.integers(0, n_clients, size=n_requests)
    arrivals = np.sort(rng.uniform(0.0, spread_s, size=n_requests))
    return [
        Request(
            rid=rid,
            client=f"client{int(clients[rid])}",
            gen_len=int(gen_lens[rid]),
            arrival_s=float(arrivals[rid]),
        )
        for rid in range(n_requests)
    ]


def run_step(requests, n_slots, steps_per_interval, policy="throughput-max",
             cap_w=None):
    """Step executor; returns (sched, truth, per-step modelled watts).

    ``truth[rid]`` is the request's ground-truth energy: each step's
    measured energy split equally across the requests that decoded a real
    token in it (occupancy is exact at step granularity, so this is the
    reference both billing schemes are scored against).
    """
    sched = ContinuousBatch(
        EnergyPricer(j_per_token=POWER(n_slots) * STEP_S / n_slots),
        get_policy(policy),
        n_slots=n_slots,
        cap_w=cap_w,
        power_of_batch=POWER,
    )
    truth: dict[int, float] = {}
    step_watts: list[float] = []
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    now = 0.0
    while True:
        while pending and pending[0].arrival_s <= now + 1e-12:
            sched.submit(pending.pop(0))
        sched.admit(now)
        if not sched.live_rids:
            if pending:
                now = max(now, pending[0].arrival_s)
                continue
            break
        interval_j = 0.0
        for _ in range(steps_per_interval):
            if not sched.live_rids:
                break
            watts = POWER(sched.n_active)
            rec = sched.step_billing(1, decoded_slots=sched.n_active)
            e = watts * STEP_S * BIAS
            for rid in rec.rids:
                truth[rid] = truth.get(rid, 0.0) + e / len(rec.rids)
            interval_j += watts * STEP_S
            step_watts.append(watts)
            now += STEP_S
            while pending and pending[0].arrival_s <= now + 1e-12:
                sched.submit(pending.pop(0))
        sealed = sched.seal_interval()
        if sealed is not None:
            sched.settle_interval(sealed.index, interval_j * BIAS)
    return sched, truth, step_watts


def run_wave(requests, max_batch, policy="throughput-max"):
    """Wave executor on the same workload; returns (sched, truth).

    Each wave decodes every member to its longest request; ground truth
    still splits each step's energy across the requests *really* decoding
    (members past their gen_len are padding), which is exactly the signal
    whole-wave token-share billing smears.
    """
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=POWER(max_batch) * STEP_S / max_batch),
        get_policy(policy),
        max_batch=max_batch,
        power_of_batch=POWER,
    )
    truth: dict[int, float] = {}
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    now = 0.0
    while True:
        while pending and pending[0].arrival_s <= now + 1e-12:
            sched.submit(pending.pop(0))
        wave = sched.next_wave(now)
        if wave is None:
            if pending:
                now = max(now, pending[0].arrival_s)
                continue
            break
        k = sched.waves[-1].index
        b = len(wave)
        steps = max(r.gen_len - r.done_tokens for r in wave)
        remaining = {r.rid: r.gen_len - r.done_tokens for r in wave}
        watts = POWER(b)
        for i in range(steps):
            active = [rid for rid, rem in remaining.items() if rem > i]
            e = watts * STEP_S * BIAS
            for rid in active:
                truth[rid] = truth.get(rid, 0.0) + e / len(active)
        sched.complete_wave(k, steps)
        sched.reconcile(k, watts * STEP_S * steps * BIAS)
        now += STEP_S * steps
    return sched, truth


def billing_error(sched, truth) -> float:
    """Mean relative |billed − truth| over requests with nonzero truth."""
    errs = []
    for row in sched.report_rows():
        t = truth.get(row["rid"], 0.0)
        if t > 0:
            errs.append(abs(row["measured_j"] - t) / t)
    return float(np.mean(errs)) if errs else 0.0


def conservation_leak(sched) -> float:
    """Relative |billed + overhead − settled| (0 = exact ledger)."""
    overhead = getattr(sched, "overhead_j", 0.0)
    billed = sum(r["measured_j"] for r in sched.report_rows())
    return abs(billed + overhead - sched.spent_j) / max(abs(sched.spent_j), 1.0)


def run(n_requests: int, seed: int, json_path: str | None = None) -> int:
    report = BenchReport("serving_churn", {"requests": n_requests, "seed": seed})
    n_slots = 8
    spread_s = n_requests * 2.0 * STEP_S  # arrivals overlap decode heavily
    requests = make_workload(n_requests, n_clients=3, spread_s=spread_s, seed=seed)
    clone = lambda: [  # noqa: E731 — executors mutate their requests
        Request(rid=r.rid, client=r.client, gen_len=r.gen_len,
                arrival_s=r.arrival_s)
        for r in requests
    ]

    step_sched, step_truth, _ = run_step(clone(), n_slots, steps_per_interval=4)
    wave_sched, wave_truth = run_wave(clone(), n_slots)
    step_err = billing_error(step_sched, step_truth)
    wave_err = billing_error(wave_sched, wave_truth)
    report.emit("serving_churn_step_err_pct", step_err * 100.0,
                "mean per-request billing error, step granularity")
    report.emit("serving_churn_wave_err_pct", wave_err * 100.0,
                "mean per-request billing error, wave granularity")

    cap_w = POWER(n_slots) - 1.0  # a full batch would blow the cap
    cap_sched, _, cap_watts = run_step(
        clone(), n_slots, steps_per_interval=4, policy="cap-strict", cap_w=cap_w
    )
    overshoot = sum(1 for w in cap_watts if w > cap_w + CAP_EPS_W)
    report.emit("serving_churn_cap_overshoot_steps", float(overshoot),
                f"steps over {cap_w:.0f} W under cap-strict churn")
    report.emit("serving_churn_cap_peak_w", max(cap_watts) if cap_watts else 0.0,
                "peak modelled step power under cap-strict churn")

    failures = []
    if not report.gate("step_beats_wave", step_err <= STEP_VS_WAVE_MARGIN * wave_err,
                       value=step_err / wave_err if wave_err else float("inf"),
                       limit=STEP_VS_WAVE_MARGIN,
                       detail="step billing error / wave billing error"):
        failures.append(
            f"step billing error {step_err:.3%} not below "
            f"{STEP_VS_WAVE_MARGIN:.0%} of wave error {wave_err:.3%}"
        )
    if not report.gate("cap_no_overshoot", not overshoot,
                       value=float(overshoot), limit=0.0,
                       detail="decode steps over the cap under cap-strict"):
        failures.append(
            f"cap-strict admission let {overshoot} step(s) over the "
            f"{cap_w:.0f} W cap (peak {max(cap_watts):.1f} W)"
        )
    for label, s in (("step", step_sched), ("wave", wave_sched),
                     ("cap", cap_sched)):
        leak = conservation_leak(s)
        if not report.gate(f"conserve_{label}",
                           math.isfinite(leak) and leak <= CONSERVE_RTOL,
                           value=leak, limit=CONSERVE_RTOL,
                           detail="relative billing-ledger leak"):
            failures.append(f"{label} ledger leaks energy (rel {leak:.3g})")
    for label, s in (("step", step_sched), ("wave", wave_sched)):
        if len(s.finished) != n_requests:
            failures.append(
                f"{label} executor finished {len(s.finished)}/{n_requests}"
            )

    report.finish(failures, json_path=json_path)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"OK: step-granularity billing error {step_err:.3%} < "
          f"{STEP_VS_WAVE_MARGIN:.0%} x wave error {wave_err:.3%} on the same "
          f"churn workload; cap-strict held {cap_w:.0f} W at all "
          f"{len(cap_watts)} step boundaries (peak {max(cap_watts):.1f} W)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    add_json_arg(ap)
    args = ap.parse_args(argv)
    n_requests = args.requests if args.requests is not None else (
        24 if args.smoke else 96)
    return run(n_requests, args.seed, json_path=args.json)


if __name__ == "__main__":
    sys.exit(main())
