"""Paper Table II: power-reading error vs sampling rate (block averaging).

12 V / 10 A module, 0.5 A and 1 A loads, 128 k samples at 20 kHz,
averaged down to 10/5/1/0.5 kHz.  The reproduction target is the 1/sqrt(N)
structure (paper: 0.72 -> 0.117 W_rms from 20 kHz -> 0.5 kHz at 1 A).
"""
from __future__ import annotations

import io

import numpy as np

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.core.calibration import calibrate

from .common import emit, timer

RATES = {20000: 1, 10000: 2, 5000: 4, 1000: 20, 500: 40}
PAPER_STD_1A = {20000: 0.722, 10000: 0.511, 5000: 0.362, 1000: 0.163, 500: 0.117}


def _collect_watts(amps: float, n_samples: int, seed: int) -> np.ndarray:
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 0.0), seed=seed)
    ps = PowerSensor(dev)
    calibrate(ps, {0: 12.0}, n_samples=8000)
    dev.firmware.dut.loads[0] = ConstantLoad(12.0, amps)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    ps.run_for(n_samples / 20000.0)
    ps.set_dump_file(None)
    return np.array(
        [float(l.split()[4]) for l in buf.getvalue().splitlines() if l and l[0].isdigit()]
    )


def run(n_samples: int = 128_000) -> None:
    for amps in (0.5, 1.0):
        with timer() as t:
            watts = _collect_watts(amps, n_samples, seed=11)
        expected = 12.0 * amps
        for rate, block in RATES.items():
            w = watts[: len(watts) // block * block].reshape(-1, block).mean(axis=1)
            err = w - expected
            derived = (
                f"load={amps}A min={err.min():.3f} max={err.max():.3f} "
                f"pp={np.ptp(err):.3f} std={err.std():.3f}"
            )
            if amps == 1.0:
                derived += f" paper_std={PAPER_STD_1A[rate]}"
            emit(f"table2/fs{rate}", t.us / len(RATES) / 2, derived)
