"""Fleet decode benchmark: pooled fused decode vs per-device polling.

The pooled decoder exists to amortise the receiver's fixed per-poll
numpy overhead across the whole fleet — at head-node scale (64 links,
~20 frames per link per 1 ms tick) that overhead, not the arithmetic, is
the bottleneck.  This benchmark replays identical pre-recorded chunked
traffic through both paths and gates:

* **speedup** — the pooled path must decode ≥ 4× the per-device path's
  frames/s on small-chunk fleet traffic;
* **conformance** — both runs must agree *bit-for-bit* on every device's
  accumulated energy (the fused pass is a pure reorganisation of the
  same float ops, not an approximation);
* **golden replay** (``--replay``) — every committed golden scenario
  drained through a pooled `FleetMonitor` must reproduce the in-process
  per-device reference energies exactly.

    PYTHONPATH=src python -m benchmarks.fleet_decode [--smoke] [--replay]
                                                     [--json PATH]
"""
from __future__ import annotations

import argparse
import time

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.stream import FleetMonitor

from .common import BenchReport, add_json_arg

GOLDEN_SCENARIOS = [
    "serve-wave",
    "serve-churn",
    "governor-step",
    "chaos-dropout",
    "chaos-disconnect",
]

CHUNK_S = 0.001  # 1 ms head ticks: ~20 frames per link per poll


class _ScriptDevice:
    """Serve pre-recorded ``(bytes, t_s)`` chunks, one per ``read()``.

    Replays the exact same wire traffic into both decode paths with zero
    generation cost inside the timed region.
    """

    def __init__(self, chunks):
        self._chunks = chunks
        self._i = 0
        self.t_s = 0.0

    def write(self, data: bytes) -> None:
        pass

    def read(self, max_bytes=None) -> bytes:
        if self._i >= len(self._chunks):
            return b""
        data, t_s = self._chunks[self._i]
        self._i += 1
        self.t_s = t_s
        return data

    def advance(self, dt_s: float) -> None:
        pass

    @property
    def pending_bytes(self) -> int:
        return 0

    @property
    def exhausted(self) -> bool:
        return self._i >= len(self._chunks)


def _build(n_devices: int, n_chunks: int) -> dict[str, PowerSensor]:
    """N sensors whose transports replay freshly recorded chunk scripts.

    Each sensor handshakes against its own deterministic virtual device
    (seeded), then the device is swapped for a script of that device's
    subsequent traffic — so two `_build` calls with the same arguments
    produce byte-identical streams into independent sensors.
    """
    sensors: dict[str, PowerSensor] = {}
    for i in range(n_devices):
        inner = make_device(
            ["pcie8pin-20a"], ConstantLoad(12.0, 2.0 + 0.1 * (i % 8)), seed=i
        )
        ps = PowerSensor(inner, ring_capacity=1 << 14)
        chunks = []
        for _ in range(n_chunks):
            inner.advance(CHUNK_S)
            chunks.append((inner.read(), inner.t_s))
        ps.device = _ScriptDevice(chunks)
        sensors[f"dev{i}"] = ps
    return sensors


def _drain_solo(sensors) -> tuple[int, float]:
    frames = 0
    t0 = time.perf_counter()
    while True:
        got = 0
        for ps in sensors.values():
            got += ps.poll()
        frames += got
        if got == 0:
            break
    return frames, time.perf_counter() - t0


def _drain_pooled(monitor) -> tuple[int, float]:
    frames = 0
    t0 = time.perf_counter()
    while True:
        got = monitor.poll_all()
        frames += got
        if got == 0:
            break
    return frames, time.perf_counter() - t0


def bench_speedup(
    n_devices: int,
    n_chunks: int,
    min_ratio: float,
    report: BenchReport,
    reps: int = 3,
) -> list[str]:
    failures: list[str] = []

    # best-of-N per path: each rep replays freshly built identical
    # traffic, and the max rate stands in for the undisturbed machine —
    # a single timed pass is far too exposed to scheduler noise for a
    # ratio gate
    solo_rate = pooled_rate = 0.0
    solo_frames = pooled_frames = 0
    solo = monitor = None
    for _ in range(max(int(reps), 1)):
        solo = _build(n_devices, n_chunks)
        solo_frames, wall = _drain_solo(solo)
        if wall > 0:
            solo_rate = max(solo_rate, solo_frames / wall)
        monitor = FleetMonitor(_build(n_devices, n_chunks))
        monitor.enable_pool()
        pooled_frames, wall = _drain_pooled(monitor)
        if wall > 0:
            pooled_rate = max(pooled_rate, pooled_frames / wall)
    ratio = pooled_rate / solo_rate if solo_rate > 0 else 0.0
    report.emit(
        "fleet_decode_solo_frames_per_s", solo_rate,
        f"{n_devices} links, per-device polling",
    )
    report.emit(
        "fleet_decode_pooled_frames_per_s", pooled_rate,
        f"{n_devices} links, fused pooled decode",
    )
    report.emit("fleet_decode_speedup", ratio, "pooled / per-device")
    report.record("fleet_decode_pool_fused_frames", monitor.pool.fused_frames)

    if not report.gate(
        "decode:frame-count", solo_frames == pooled_frames,
        value=pooled_frames, limit=solo_frames,
    ):
        failures.append(
            f"frame counts diverge: solo {solo_frames} vs pooled {pooled_frames}"
        )
    if not report.gate(
        "decode:fused-path-used", monitor.pool.fused_frames == pooled_frames,
        value=monitor.pool.fused_frames, limit=pooled_frames,
        detail="clean uniform traffic must not hit the fallback",
    ):
        failures.append("pooled run fell back to the solo decode path")
    mismatched = [
        name
        for name in solo
        if solo[name].read().consumed_joules
        != monitor[name].read().consumed_joules
    ]
    if not report.gate(
        "decode:energy-bit-identical", not mismatched, value=len(mismatched),
        limit=0,
    ):
        failures.append(f"pooled energies diverge on {mismatched}")
    if not report.gate(
        "decode:speedup", ratio >= min_ratio, value=ratio, limit=min_ratio,
        detail="pooled decoded-frames/s over per-device decoded-frames/s",
    ):
        failures.append(
            f"pooled speedup {ratio:.2f}x below the {min_ratio:.1f}x gate"
        )
    return failures


def bench_replay_conformance(report: BenchReport) -> list[str]:
    """Golden corpus through a pooled FleetMonitor vs the solo reference."""
    from repro.replay import TraceArchive
    from repro.replay.replay import replay_sensor

    failures: list[str] = []
    for scenario in GOLDEN_SCENARIOS:
        arc = TraceArchive.load(f"tests/goldens/{scenario}.npz")
        refs: dict[str, float] = {}
        for name, trace in arc.devices.items():
            ps = replay_sensor(trace)
            ps.device.release_all()
            while not (
                ps.poll() == 0
                and (ps.device.exhausted or not ps.device.streaming)
            ):
                pass
            refs[name] = ps.read().consumed_joules

        monitor = FleetMonitor()
        for name, trace in arc.devices.items():
            ps = replay_sensor(trace)
            ps.device.release_all()
            monitor.add(name, ps)
        monitor.enable_pool()
        while True:
            n = monitor.poll_all()
            if n == 0 and all(
                monitor[name].device.exhausted
                or not monitor[name].device.streaming
                for name in arc.devices
            ):
                break
        for name in arc.devices:
            ok = monitor[name].read().consumed_joules == refs[name]
            if not report.gate(f"replay:{scenario}:{name}:joules", ok):
                failures.append(
                    f"{scenario}/{name}: pooled replay energy diverges"
                )
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (full fleet width, short)")
    ap.add_argument("--devices", type=int, default=None,
                    help="override the link count")
    ap.add_argument("--chunks", type=int, default=None,
                    help="override the 1 ms chunks per link")
    ap.add_argument("--replay", action="store_true",
                    help="also gate golden-corpus pooled conformance")
    add_json_arg(ap)
    args = ap.parse_args(argv)

    # the speedup comes from amortising per-poll overhead across fleet
    # *width*, so smoke keeps the full 64 links and shortens the run
    n_devices = args.devices or 64
    n_chunks = args.chunks or (60 if args.smoke else 300)
    report = BenchReport(
        "fleet_decode",
        {"devices": n_devices, "chunks": n_chunks, "smoke": bool(args.smoke)},
    )
    failures = bench_speedup(n_devices, n_chunks, 4.0, report)
    if args.replay:
        failures += bench_replay_conformance(report)
    ok = report.finish(failures, args.json)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"fleet_decode: {'ok' if ok else 'FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
