"""Paper Fig 7: PowerSensor3 vs built-in counter on a phased workload.

The DUT is the TPU-model train-step trace (the adapted workload) plus the
GPU-shaped synthetic profile; meters: PowerSensor3-sim (20 kHz),
builtin-instant (10 Hz), builtin-average (legacy).  Reported: energy
error per meter and whether each resolves the inter-phase dips.
"""
from __future__ import annotations

import numpy as np

from repro.core.dut import GpuKernelLoad
from repro.power import (
    BuiltinCounterMeter,
    PowerSensor3Meter,
    StepCost,
    V5E,
    compare_meters,
    phases_for_step,
    render_phases,
)

from .common import emit, timer


def _workloads():
    g = GpuKernelLoad(t_start_s=0.1, ramp_s=0.12, n_phases=5, phase_s=0.21, dip_s=0.004)
    t = np.linspace(0, g.t_total, 150_000)
    v, a = g.sample(t)
    yield "gpu-kernel", t, v * a, (g.t_start_s + g.ramp_s + g.phase_s, g.dip_s)

    cost = StepCost(flops=2.5e12, hbm_bytes=6e11, ici_bytes=5e10)
    tr = render_phases(phases_for_step(cost, n_layers=12), V5E,
                       idle_before_s=0.05, idle_after_s=0.05, repeat=8)
    # dip to find: the first collective phase of step 2
    marks = dict(tr.phase_marks)
    yield "tpu-train-steps", tr.times_s, tr.watts, (marks.get("coll0@1", 0.2), 0.002)


def run() -> None:
    for name, t, w, (t_dip, dip_len) in _workloads():
        with timer() as tm:
            res = compare_meters(t, w)
        truth = res["ground-truth"].true_energy_j
        for meter in ("powersensor3", "builtin-instant", "builtin-average"):
            m = res[meter]
            sees = m.captures_transient(t_dip, t_dip + dip_len, min_samples=2)
            emit(
                f"fig7/{name}/{meter}",
                tm.us / 4,
                f"E={m.energy_j:.1f}J true={truth:.1f}J err={m.energy_error_frac*100:+.2f}% "
                f"rate={m.update_rate_hz:g}Hz resolves_dip={sees}",
            )
