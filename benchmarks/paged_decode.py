"""Paged decode-attention benchmark: conformance, churny throughput, energy.

Three gated sections (the run exits nonzero unless every gate holds):

* **conformance** — the paged Pallas kernel must match the shared ragged
  oracle (`ragged_decode_ref`) on ragged batches whose lengths include 0
  and exactly-full, and ``kv_len == 0`` rows must be **exact zeros** (the
  serve loop's free/draining slots feed those rows — the NaN this PR
  fixes in the dense kernel must never come back in the paged one);
* **throughput** — a churny ragged serve workload (slots retiring and
  re-admitting at different fill stages) decoded through the paged path
  (page-indirect KV writes + page-table flash-decode over the *live*
  pages) must sustain at least the dense-cache serve path's decoded
  tokens/s (slab scatter + ragged flash-decode over the run-global
  ``S_max`` slab — the dense grid streams every allocated block whether
  or not anyone is that long);
* **energy** — `repro.power.tuner.EnergyTuner` sweeps the kernel's
  page-size × block × buffer-depth space across a DVFS ladder, scored
  marker-free by `AttributionStrategy` (changepoint-segmented per-launch
  energy), and the resulting latency × J/token Pareto front must be
  non-degenerate (>= 2 distinct points): big pages buy speed with
  over-fetched joules, so a healthy cost model cannot collapse to one
  point.

    PYTHONPATH=src python -m benchmarks.paged_decode [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import decode_attention
from repro.kernels.paged_attention import (
    PagedKVPool,
    init_page_arrays,
    pack_prefill_pages,
    paged_decode_attention,
    paged_tuner_model,
    pages_for,
    ragged_decode_ref,
)
from repro.power.tpu_model import DvfsState
from repro.power.tuner import EnergyTuner, attribution_strategy

from .common import BenchReport, add_json_arg

TOL = dict(rtol=1e-3, atol=1e-3)


# --------------------------------------------------------------------------- conformance
def _build_paged(rng, kv_lens, ps, max_pages, hkv, d):
    pool = PagedKVPool(n_pages=1 + len(kv_lens) * max_pages, page_size=ps)
    kp, vp = init_page_arrays(pool.n_pages, ps, hkv, d, jnp.float32)
    s = max_pages * ps
    kd = np.zeros((len(kv_lens), s, hkv, d), np.float32)
    vd = np.zeros_like(kd)
    slot_rids = []
    for r, ln in enumerate(kv_lens):
        if ln == 0:
            slot_rids.append(None)
            continue
        pages = pool.alloc(r, ln)
        pool.note_tokens(r, ln)
        k = rng.normal(size=(ln, hkv, d)).astype(np.float32)
        v = rng.normal(size=(ln, hkv, d)).astype(np.float32)
        kd[r, :ln], vd[r, :ln] = k, v
        kp, vp = pack_prefill_pages(
            kp, vp, jnp.asarray(k), jnp.asarray(v), jnp.asarray(pages, jnp.int32)
        )
        slot_rids.append(r)
    table = jnp.asarray(pool.table(slot_rids, max_pages))
    lens = jnp.asarray(pool.kv_lens(slot_rids))
    return kp, vp, table, lens, jnp.asarray(kd), jnp.asarray(vd)


def bench_conformance(report: BenchReport) -> list[str]:
    failures: list[str] = []
    rng = np.random.default_rng(0)
    cases = [
        # (ps, max_pages, hq, hkv, d, ragged lens incl. 0 and exactly-full)
        (16, 4, 4, 2, 64, (0, 1, 37, 64)),
        (32, 2, 8, 2, 64, (0, 33, 64)),
        (8, 3, 4, 1, 32, (24, 5, 0)),
    ]
    worst = 0.0
    zero_ok = True
    for ps, max_pages, hq, hkv, d, kv_lens in cases:
        kp, vp, table, lens, kd, vd = _build_paged(rng, kv_lens, ps, max_pages, hkv, d)
        q = jnp.asarray(rng.normal(size=(len(kv_lens), hq, d)), jnp.float32)
        out = np.asarray(paged_decode_attention(q, kp, vp, table, lens))
        ref = np.asarray(ragged_decode_ref(q, kd, vd, lens))
        err = float(np.abs(out - ref).max())
        worst = max(worst, err)
        for row, ln in enumerate(kv_lens):
            if ln == 0 and not (out[row] == 0.0).all():
                zero_ok = False
    report.emit("paged_decode_worst_abs_err", worst, "paged kernel vs ragged oracle")
    if not report.gate(
        "paged:conformance", worst <= TOL["atol"], value=worst, limit=TOL["atol"],
        detail="max |paged - ragged_decode_ref| over ragged batches",
    ):
        failures.append(f"paged kernel diverges from the ragged oracle by {worst:.2e}")
    if not report.gate(
        "paged:kv0-exact-zero", zero_ok,
        detail="kv_len == 0 rows must be exact zeros, never NaN",
    ):
        failures.append("a kv_len == 0 row was not exact zeros")
    return failures


# --------------------------------------------------------------------------- throughput
def bench_churn_throughput(report: BenchReport, smoke: bool) -> list[str]:
    """Dense-cache vs paged decode step rate on one churny ragged workload.

    Both paths run their actual serve building blocks under identical
    churn: per step, the dense path scatters the new token into a
    run-global ``(B, S_max)`` slab and flash-decodes over *all* of it
    (blocks past ``kv_len`` masked but streamed); the paged path writes
    through the page table and flash-decodes only the pages the live
    requests own.  Every ``churn_every`` steps one slot retires (one
    dead ``kv_len == 0`` step — both kernels' zero contract on the hot
    path) and is re-admitted at the prompt length.
    """
    failures: list[str] = []
    b, hq, hkv, d = 4, 4, 2, 64
    ps = 64
    prompt = 96
    s_max = 512 if smoke else 2048  # dense slab: run-global worst case
    n_steps = 24 if smoke else 80
    churn_every = 4
    max_pages = pages_for(prompt + n_steps, ps) + 1

    rng = np.random.default_rng(1)
    key = jax.random.PRNGKey(1)

    @jax.jit
    def dense_step(q, kc, vc, knew, vnew, lens, live):
        iota = jnp.arange(s_max)[None, :, None, None]
        write = (iota == lens[:, None, None, None]) & live[:, None, None, None]
        kc = jnp.where(write, knew[:, None], kc)
        vc = jnp.where(write, vnew[:, None], vc)
        new_len = jnp.where(live, lens + 1, 0)
        return decode_attention(q, kc, vc, new_len, bk=ps), kc, vc

    @jax.jit
    def paged_step(q, kp, vp, table, lens, live):
        page = jnp.where(live, table[jnp.arange(b), lens // ps], 0)
        off = lens % ps
        knew = jax.random.normal(jax.random.PRNGKey(0), (b, hkv, d), jnp.float32)
        kp = kp.at[page, off].set(knew)
        vp = vp.at[page, off].set(knew)
        new_len = jnp.where(live, lens + 1, 0)
        return paged_decode_attention(q, kp, vp, table, new_len), kp, vp

    def run_dense() -> float:
        kc = jnp.zeros((b, s_max, hkv, d), jnp.float32)
        vc = jnp.zeros_like(kc)
        lens = np.full(b, prompt, np.int64)
        live = np.ones(b, bool)
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
        knew = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
        # warm the compile outside the timed region
        o, kc_w, _ = dense_step(q, kc, vc, knew, knew, jnp.asarray(lens), jnp.asarray(live))
        o.block_until_ready()
        t0 = time.perf_counter()
        for step in range(n_steps):
            if step % churn_every == churn_every - 1:
                slot = step // churn_every % b
                live[slot], lens[slot] = False, 0  # retire: one dead step
            elif step % churn_every == 0 and not live[step // churn_every % b]:
                slot = step // churn_every % b
                live[slot], lens[slot] = True, prompt  # re-admit at prompt
            o, kc, vc = dense_step(
                q, kc, vc, knew, knew, jnp.asarray(lens), jnp.asarray(live)
            )
            o.block_until_ready()
            lens[live] += 1
        return time.perf_counter() - t0

    def run_paged() -> float:
        pool = PagedKVPool(n_pages=1 + b * max_pages, page_size=ps)
        kp, vp = init_page_arrays(pool.n_pages, ps, hkv, d, jnp.float32)
        slot_rids = []
        for r in range(b):
            pool.note_tokens(r, prompt) if pool.alloc(r, prompt + n_steps) else None
            slot_rids.append(r)
        next_rid = b
        q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
        table = jnp.asarray(pool.table(slot_rids, max_pages))
        lens = jnp.asarray(pool.kv_lens(slot_rids))
        live = jnp.asarray([r is not None for r in slot_rids])
        o, kp_w, _ = paged_step(q, kp, vp, table, lens, live)
        o.block_until_ready()
        t0 = time.perf_counter()
        for step in range(n_steps):
            if step % churn_every == churn_every - 1:
                slot = step // churn_every % b
                if slot_rids[slot] is not None:
                    pool.free(slot_rids[slot])
                    slot_rids[slot] = None  # retire: pages back to the pool
            elif step % churn_every == 0 and slot_rids[step // churn_every % b] is None:
                slot = step // churn_every % b
                if pool.alloc(next_rid, prompt + n_steps) is not None:
                    pool.note_tokens(next_rid, prompt)
                    slot_rids[slot] = next_rid
                    next_rid += 1
            table = jnp.asarray(pool.table(slot_rids, max_pages))
            lens = jnp.asarray(pool.kv_lens(slot_rids))
            live = jnp.asarray([r is not None for r in slot_rids])
            o, kp, vp = paged_step(q, kp, vp, table, lens, live)
            o.block_until_ready()
            for r in slot_rids:
                if r is not None:
                    pool.append(r)
        return time.perf_counter() - t0

    # best-of-N: single timed passes are too exposed to scheduler noise
    reps = 2 if smoke else 3
    dense_s = min(run_dense() for _ in range(reps))
    paged_s = min(run_paged() for _ in range(reps))
    dense_tps = b * n_steps / dense_s
    paged_tps = b * n_steps / paged_s
    ratio = paged_tps / dense_tps if dense_tps else 0.0
    report.emit(
        "paged_decode_dense_tokens_per_s", dense_tps,
        f"dense slab S_max={s_max}, churny ragged workload",
    )
    report.emit(
        "paged_decode_paged_tokens_per_s", paged_tps,
        f"page size {ps}, {max_pages}-page tables, same workload",
    )
    report.emit("paged_decode_speedup", ratio, "paged / dense decoded tokens/s")
    if not report.gate(
        "paged:throughput", ratio >= 1.0, value=ratio, limit=1.0,
        detail="paged must sustain the dense-cache serve path's tokens/s",
    ):
        failures.append(
            f"paged path decoded {ratio:.2f}x the dense rate (gate: >= 1.0x)"
        )
    return failures


# --------------------------------------------------------------------------- energy sweep
def bench_energy_sweep(report: BenchReport, smoke: bool) -> list[str]:
    failures: list[str] = []
    b = 64
    kernel = paged_tuner_model(b=b, kv_mean=600.0)  # ragged mean, off page grid
    tuner = EnergyTuner()
    strategy = attribution_strategy(seed=0, n_trials=3 if smoke else 7)
    dvfs = [DvfsState(1.0), DvfsState(0.85), DvfsState(0.7)]
    res = tuner.tune(kernel, strategy, dvfs_states=dvfs)
    front = res.pareto_front()

    # the frontier in serving units: per-step latency x J/token
    pts = [(r.time_s * 1e6, r.joules / b * 1e3, r.config, r.dvfs_scale) for r in front]
    for i, (lat_us, mj_tok, cfg, scale) in enumerate(pts):
        report.emit(
            f"paged_pareto_{i}_latency_us", lat_us,
            f"page={cfg['page_size']} bk={cfg['bk']} depth={cfg['depth']} "
            f"dvfs={scale:.2f}: {mj_tok:.4f} mJ/token",
        )
        report.record(f"paged_pareto_{i}_mj_per_token", mj_tok)
    report.emit("paged_tuner_configs", float(len(res.records)),
                f"{len(front)}-point Pareto front, "
                f"{res.total_tuning_time_s:.1f}s modelled tuning time")
    fast, eff = res.fastest(), res.most_efficient()
    report.record("paged_tuner_fastest_us", fast.time_s * 1e6)
    report.record("paged_tuner_most_efficient_mj_tok", eff.joules / b * 1e3)

    distinct = {(round(lat, 3), round(mj, 6)) for lat, mj, _, _ in pts}
    if not report.gate(
        "paged:pareto-nondegenerate", len(distinct) >= 2, value=len(distinct),
        limit=2, detail="latency x J/token front must trade off, not collapse",
    ):
        failures.append(
            f"energy sweep produced a degenerate Pareto front ({len(distinct)} point)"
        )
    # the tradeoff must be real: the fastest config must not also be the
    # most efficient one (otherwise the cost model has no energy axis)
    if not report.gate(
        "paged:speed-efficiency-tradeoff",
        fast.config != eff.config or fast.dvfs_scale != eff.dvfs_scale,
        detail="fastest and most-efficient variants must differ",
    ):
        failures.append("fastest == most-efficient: cost model has no tradeoff")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    add_json_arg(ap)
    args = ap.parse_args(argv)

    report = BenchReport("paged_decode", {"smoke": bool(args.smoke)})
    failures = bench_conformance(report)
    failures += bench_churn_throughput(report, args.smoke)
    failures += bench_energy_sweep(report, args.smoke)
    ok = report.finish(failures, args.json)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"paged_decode: {'ok' if ok else 'FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
