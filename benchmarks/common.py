"""Shared benchmark plumbing: CSV emission + machine-readable JSON reports.

Every gated benchmark prints ``name,value,derived`` CSV lines (the
harness contract) and can additionally write one JSON document per run
via ``--json PATH`` — measured values, gate outcomes and the overall
pass/fail — so perf trajectories can be diffed across PRs with
``tools/perf_diff.py --bench``.
"""
from __future__ import annotations

import json
import time


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


class timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.dt * 1e6


class BenchReport:
    """Collects one benchmark run's metrics and gates for JSON export.

    ``emit`` mirrors the module-level CSV emitter while recording the
    value; ``gate`` records one named pass/fail check; ``finish`` folds
    in a benchmark's legacy failure-string list and writes the document
    (no-op when the caller didn't ask for ``--json``).
    """

    def __init__(self, benchmark: str, config: dict | None = None):
        self.benchmark = benchmark
        self.config = dict(config or {})
        self.metrics: dict[str, dict] = {}
        self.gates: list[dict] = []
        self.failures: list[str] = []

    def emit(self, name: str, value: float, derived: str = "") -> None:
        """Print the harness CSV line and record the metric."""
        emit(name, value, derived)
        self.record(name, value, derived)

    def record(self, name: str, value: float, derived: str = "") -> None:
        self.metrics[name] = {"value": float(value), "derived": derived}

    def gate(
        self,
        name: str,
        passed: bool,
        value: float | None = None,
        limit: float | None = None,
        detail: str = "",
    ) -> bool:
        self.gates.append(
            {
                "name": name,
                "passed": bool(passed),
                "value": None if value is None else float(value),
                "limit": None if limit is None else float(limit),
                "detail": detail,
            }
        )
        return bool(passed)

    @property
    def ok(self) -> bool:
        return not self.failures and all(g["passed"] for g in self.gates)

    def to_dict(self) -> dict:
        return {
            "benchmark": self.benchmark,
            "config": self.config,
            "metrics": self.metrics,
            "gates": self.gates,
            "failures": list(self.failures),
            "ok": self.ok,
        }

    def finish(self, failures: list[str] | None = None, json_path: str | None = None) -> bool:
        """Fold in failure strings, write the JSON document, return ok."""
        if failures:
            self.failures.extend(failures)
        if json_path:
            with open(json_path, "w") as fh:
                json.dump(self.to_dict(), fh, indent=2)
                fh.write("\n")
        return self.ok


def add_json_arg(ap) -> None:
    """Install the shared ``--json PATH`` benchmark flag."""
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="write a machine-readable result document (metrics, gates, "
             "pass/fail) for tools/perf_diff.py --bench",
    )
