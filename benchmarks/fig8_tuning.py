"""Paper Fig 8 + the 3.25× claim: energy-aware autotuning of the
Tensor-Core Beamformer (MXU edition) over block shapes × DVFS states.

Reports: the Pareto-front endpoints (fastest vs most-efficient, the
paper's 12.7 % / 21.5 % style trade) and the tuning-time ratio between
the fast-sensor methodology and the 10 Hz built-in counter (paper 3.25×).
Also validates the chosen best config numerically against ref.py
(small-shape interpret-mode run).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.beamformer import beamform, beamform_ref, tuner_kernel_model
from repro.power import DvfsState, EnergyTuner, fast_sensor_strategy, tuning_speedup

from .common import emit, timer


def run() -> None:
    kernel = tuner_kernel_model(m=4096, n=4096, k=4096)
    dvfs = DvfsState.sweep(0.6, 1.0, 10)  # paper: 10 clock frequencies

    with timer() as t:
        speedup, fast, slow = tuning_speedup(kernel, dvfs_states=dvfs)
    n_cfg = len(fast.records)
    best = fast.fastest()
    eff = fast.most_efficient()
    slowdown = (1 / eff.tflops - 1 / best.tflops) * best.tflops * 100 if eff.tflops else 0
    gain = (eff.tflop_per_j / best.tflop_per_j - 1) * 100
    emit(
        "fig8/pareto",
        t.us / max(n_cfg, 1),
        f"configs={n_cfg} fastest={best.tflops:.1f}TFLOP/s@{best.tflop_per_j:.2f}TFLOP/J "
        f"cfg={best.config}|dvfs={best.dvfs_scale:.2f} "
        f"efficient=+{gain:.1f}%eff/-{abs(slowdown):.1f}%speed (paper: +12.7%/-21.5%)",
    )
    emit(
        "fig8/tuning_speedup",
        t.us / max(n_cfg, 1),
        f"fast_sensor={fast.total_tuning_time_s:.0f}s builtin={slow.total_tuning_time_s:.0f}s "
        f"speedup={speedup:.2f}x paper=3.25x",
    )

    # numeric validation of the winning config at reduced shape
    cfg = {k: min(v, 128) if isinstance(v, int) else v for k, v in best.config.items()}
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    m = n = k = 256
    ar, ai = (jax.random.normal(kk, (m, k), jnp.float32).astype(jnp.bfloat16) for kk in ks[:2])
    br, bi = (jax.random.normal(kk, (k, n), jnp.float32).astype(jnp.bfloat16) for kk in ks[2:])
    cr, ci = beamform(ar, ai, br, bi, bm=cfg["bm"], bn=cfg["bn"], bk=cfg["bk"],
                      karatsuba=cfg["karatsuba"])
    rr, ri = beamform_ref(ar, ai, br, bi)
    err = float(jnp.max(jnp.abs(cr - rr)) + jnp.max(jnp.abs(ci - ri)))
    emit("fig8/winner_validates", 0.0, f"reduced-shape max|err|={err:.3f} (bf16) ok={err < 1.0}")
