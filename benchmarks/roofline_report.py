"""§Roofline: per (arch × shape) terms from the dry-run cache.

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
emits one CSV row per cell: the three terms, the bottleneck, MODEL_FLOPS/
HLO_FLOPs and the roofline fraction.  Also regenerates the markdown table
used by EXPERIMENTS.md (experiments/roofline_table.md).
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

DRYRUN_DIR = "experiments/dryrun"


def load_cells(tag: str | None = None, mesh: str | None = "pod16x16") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if mesh and d.get("mesh") != mesh:
            continue
        if tag and d.get("tag") != tag:
            continue
        cells.append(d)
    return cells


def markdown_table(cells: list[dict]) -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bottleneck | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for d in cells:
        if d.get("status") != "ok" or "roofline" not in d:
            lines.append(f"| {d.get('arch')} | {d.get('shape')} | — | — | — | "
                         f"{d.get('status')} | — | — |")
            continue
        r = d["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"**{r['bottleneck']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


def run() -> None:
    cells = load_cells(tag="baseline")
    ok = 0
    for d in cells:
        if d.get("status") != "ok":
            emit(f"roofline/{d.get('arch')}__{d.get('shape')}", 0.0,
                 f"status={d.get('status')}")
            continue
        if "roofline" not in d:
            continue
        r = d["roofline"]
        ok += 1
        emit(
            f"roofline/{r['arch']}__{r['shape']}",
            d.get("compile_s", 0.0) * 1e6,
            f"tc={r['t_compute_s']*1e3:.2f}ms tm={r['t_memory_s']*1e3:.2f}ms "
            f"tn={r['t_collective_s']*1e3:.2f}ms bn={r['bottleneck']} "
            f"useful={r['useful_flops_ratio']:.2f} frac={r['roofline_fraction']:.3f}",
        )
    if cells:
        os.makedirs("experiments", exist_ok=True)
        with open("experiments/roofline_table.md", "w") as f:
            f.write(markdown_table(cells) + "\n")
    emit("roofline/summary", 0.0, f"cells={len(cells)} with_roofline={ok}")
