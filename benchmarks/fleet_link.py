"""Fleet link benchmark: 64 socket links at 20 kHz each, loss-free.

Gates the `repro.net` transport the way the receiver benchmark gates the
decode hot path (the head decodes all links through the pooled fused
pass — see `benchmarks/fleet_decode.py` for the decode-cost gate):

* **clean sustain** — a `FleetHead` over N wall-clock-driven virtual
  devices (one `DeviceServer`, one TCP link per device) must hold every
  link at the device's native 20 kHz frame rate with *zero* dropped
  frames and *zero* resync-discarded bytes: after the run each link's
  ring must be gap-free (every inter-frame delta exactly one 50 µs
  frame) and must have landed ≥ 90 % of the frames the wall clock
  generated (backpressure may delay the tail, never drop it);
* **disconnect → reacquire** — one link is severed mid-run
  (`DeviceServer.drop`); its device must be reported ``lost`` while
  down, reacquire automatically (reconnects ≥ 1, ``healthy``, fresh
  frames landing), and every *other* link must ride through untouched
  (still gap-free, still zero drops).

    PYTHONPATH=src python -m benchmarks.fleet_link [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import ConstantLoad, make_device
from repro.core.firmware import FRAME_US
from repro.net import DeviceServer, FleetHead

from .common import BenchReport, add_json_arg

TICK_US = FRAME_US  # one frame per 50 µs: 20 kHz


def _build(n_devices: int):
    devices = {
        f"dev{i}": make_device(
            ["pcie8pin-20a"], ConstantLoad(12.0, 2.0 + 0.25 * i), seed=i
        )
        for i in range(n_devices)
    }
    server = DeviceServer(devices, drive=True)
    head = FleetHead(
        {name: server.endpoint for name in devices},
        window_s=0.05,
        ring_capacity=1 << 16,
        stale_after_s=0.05,
        lost_after_s=0.25,
    )
    return server, head


def _link_report(head: FleetHead, name: str) -> dict:
    ps = head[name]
    block = ps.ring.latest()
    diffs = np.diff(block.times_s) if len(block) > 1 else np.array([])
    frame_s = TICK_US * 1e-6
    return {
        "frames": len(block),
        "dropped_frames": int(ps.dropped_frames),
        "dropped_bytes": int(ps.dropped_bytes),
        "gap_free": bool(
            len(diffs) and np.allclose(diffs, frame_s, rtol=0, atol=1e-9)
        ),
        "max_gap_us": float(diffs.max() * 1e6) if len(diffs) else 0.0,
    }


def bench_clean_sustain(n_devices: int, seconds: float, report: BenchReport) -> list[str]:
    failures: list[str] = []
    server, head = _build(n_devices)
    try:
        t0 = time.perf_counter()
        head.run_for(seconds, tick_s=0.001)
        wall = time.perf_counter() - t0
        # stop generating (the server reads `drive` every tick), then drain
        # the in-flight tail: delayed is fine, dropped is not.  Quiescence
        # must hold across the *whole* path — client chunk buffers AND the
        # server's per-link out-queues — and must hold for a settle window,
        # because the client side can look momentarily idle while the
        # server pump is still moving the device backlog onto the wire.
        server.drive = False
        deadline = time.monotonic() + 60.0
        quiet = 0
        while time.monotonic() < deadline:
            n = head.poll()
            stats = server.stats()
            idle = (
                not server.driving
                and n == 0
                and all(
                    head[name].device.buffered_chunks == 0
                    for name in head.endpoints
                )
                and all(s["pending_out_bytes"] == 0 for s in stats.values())
            )
            quiet = quiet + 1 if idle else 0
            if quiet >= 25:
                break
            if idle:
                time.sleep(0.002)
        total_frames = 0
        expect = seconds * 1e6 / TICK_US
        for name in sorted(head.endpoints):
            link = _link_report(head, name)
            total_frames += link["frames"]
            if not report.gate(
                f"clean:{name}:zero-drops",
                link["dropped_frames"] == 0 and link["dropped_bytes"] == 0,
                value=link["dropped_frames"] + link["dropped_bytes"],
                limit=0,
            ):
                failures.append(f"{name}: dropped {link}")
            if not report.gate(
                f"clean:{name}:gap-free",
                link["gap_free"],
                value=link["max_gap_us"],
                limit=TICK_US,
                detail="every inter-frame delta must be one 50 µs frame",
            ):
                failures.append(f"{name}: stream gap ({link['max_gap_us']:.1f} µs)")
            if not report.gate(
                f"clean:{name}:rate",
                link["frames"] >= 0.9 * expect,
                value=link["frames"],
                limit=0.9 * expect,
                detail="ring frames vs wall-clock 20 kHz",
            ):
                failures.append(
                    f"{name}: {link['frames']} frames < 90% of {expect:.0f}"
                )
        report.emit(
            "fleet_link_frames_per_s", total_frames / wall,
            f"{n_devices} links, {seconds:.2f} s wall",
        )
        report.emit(
            "fleet_link_khz_per_link", total_frames / wall / n_devices / 1e3,
            "per-link sustained decode rate",
        )
        bp = sum(
            head.link_stats()[n]["backpressure_waits"] for n in head.endpoints
        )
        report.record("fleet_link_backpressure_waits", bp)
    finally:
        head.close()
        server.close()
    return failures


def bench_disconnect_reacquire(
    n_devices: int, seconds: float, report: BenchReport
) -> list[str]:
    failures: list[str] = []
    server, head = _build(n_devices)
    victim = "dev0"
    try:
        head.run_for(seconds / 2, tick_s=0.001)
        server.drop(victim)
        # observe the lost state (poll without the reconnect maintenance)
        saw_lost = False
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            head.monitor.poll_all()
            if head.device_health()[victim].state == "lost":
                saw_lost = True
                break
            time.sleep(0.002)
        if not report.gate("disconnect:lost-reported", saw_lost):
            failures.append(f"{victim} never reported lost after drop")
        # now reacquire: full poll() redials and restreams
        h0 = head[victim].ring.head
        t_down = time.monotonic()
        reacquired = False
        deadline = time.monotonic() + 30.0
        while time.monotonic() < deadline:
            head.poll()
            if (
                head.device_health()[victim].healthy
                and head[victim].ring.head > h0 + 50
            ):
                reacquired = True
                break
            time.sleep(0.002)
        reacquire_s = time.monotonic() - t_down
        if not report.gate("disconnect:reacquired", reacquired):
            failures.append(f"{victim} did not reacquire within 30 s")
        report.emit("fleet_link_reacquire_ms", reacquire_s * 1e3,
                    "lost -> healthy with fresh frames")
        if not report.gate(
            "disconnect:reconnect-counted", head.reconnects[victim] >= 1,
            value=head.reconnects[victim], limit=1,
        ):
            failures.append(f"{victim} reconnects not counted")
        head.run_for(seconds / 4, tick_s=0.001)
        # every *other* link must ride through untouched
        for name in sorted(head.endpoints):
            if name == victim:
                continue
            link = _link_report(head, name)
            ok = (
                link["dropped_frames"] == 0
                and link["dropped_bytes"] == 0
                and link["gap_free"]
            )
            if not report.gate(f"disconnect:{name}:unaffected", ok):
                failures.append(f"{name} disturbed by {victim} drop: {link}")
    finally:
        head.close()
        server.close()
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (4 links, short)")
    ap.add_argument("--devices", type=int, default=None,
                    help="override the link count")
    add_json_arg(ap)
    args = ap.parse_args(argv)

    n_devices = args.devices or (4 if args.smoke else 64)
    seconds = 0.4 if args.smoke else 1.5
    report = BenchReport(
        "fleet_link", {"devices": n_devices, "seconds": seconds,
                       "smoke": bool(args.smoke)},
    )
    failures = bench_clean_sustain(n_devices, seconds, report)
    failures += bench_disconnect_reacquire(n_devices, seconds, report)
    ok = report.finish(failures, args.json)
    for f in failures:
        print(f"FAIL: {f}")
    print(f"fleet_link: {'ok' if ok else 'FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
