"""Paper Table I: theoretical worst-case accuracy per sensor module.

Reproduces the ±mV/±A/±W numbers from the noise model and reports the
relative deviation from the paper's published values.
"""
from __future__ import annotations

from repro.core.sensors import MODULE_CATALOG, table1

from .common import emit, timer

PAPER = {
    "slot-10a-12v": (28.6, 0.35, 4.2),
    "slot-10a-3v3": (19.9, 0.35, 1.2),
    "usb-c": (28.6, 0.35, 7.0),
    "pcie8pin-20a": (28.6, 0.41, 5.0),
}


def run() -> None:
    with timer() as t:
        rows = table1()
    for row in rows:
        key = row["module"]
        if key in PAPER:
            eu, ei, ep = PAPER[key]
            dev = max(
                abs(row["voltage_mV"] - eu) / eu,
                abs(row["current_A"] - ei) / ei,
                abs(row["power_W"] - ep) / ep,
            )
            derived = (
                f"Eu={row['voltage_mV']:.1f}mV Ei={row['current_A']:.2f}A "
                f"Ep={row['power_W']:.2f}W paper=({eu}|{ei}|{ep}) maxdev={dev*100:.1f}%"
            )
        else:
            derived = (
                f"Eu={row['voltage_mV']:.1f}mV Ei={row['current_A']:.2f}A "
                f"Ep={row['power_W']:.2f}W (extrapolated module)"
            )
        emit(f"table1/{key}", t.us / len(rows), derived)
