"""Per-kernel attribution accuracy vs sampling rate (the Fig 5 argument).

The paper's headline claim is that 20 kHz sampling is *essential* to see
individual kernels in the power trace.  This benchmark makes that claim
quantitative for the attribution subsystem:

* a synthetic workload of 5 distinct kernel phases (plus an inter-step
  gap) is played through the **full virtual-sensor chain** at 20 kHz,
  with one time-synced marker per step;
* marker-free changepoint segmentation must recover every phase boundary
  within ±2 ms, and marker-aligned attribution must recover per-kernel
  energy within 5 % of ground truth;
* the same pipeline fed from builtin-counter-rate samples (100 Hz, 10 Hz)
  demonstrably fails: missed phases and >25 % energy error.

Exits nonzero when the 20 kHz chain misses its accuracy targets or the
10 Hz counter *stops failing* (both would mean the model drifted), so CI
can run ``--smoke`` as a regression gate.

    PYTHONPATH=src python -m benchmarks.attrib_accuracy [--smoke]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.attrib import attribute, render_text, segment_trace, timeline_spans
from repro.core import ConstantLoad, PowerSensor, TraceLoad, make_device
from repro.core.calibration import calibrate
from repro.power import BuiltinCounterMeter, V5E, Phase, render_phases

from .common import BenchReport, add_json_arg, emit

BOUNDARY_TOL_S = 2e-3
ENERGY_TOL = 0.05
LOW_RATE_FAIL_ERR = 0.25


def _hbm_phase(name: str, duration_s: float, watts: float) -> Phase:
    """A phase whose average power is `watts` on V5E (via the HBM term)."""
    rate = max(watts - V5E.p_static, 0.0) / V5E.e_hbm_byte
    return Phase(name, duration_s, hbm_bytes=rate * duration_s)


def build_workload() -> list[Phase]:
    """5 distinct kernel phases + inter-step gap, all adjacent powers distinct."""
    return [
        _hbm_phase("gap", 0.006, V5E.p_static),
        _hbm_phase("embed", 0.012, 95.0),
        _hbm_phase("attn", 0.028, 185.0),
        _hbm_phase("collective", 0.008, 75.0),
        _hbm_phase("ffn", 0.022, 150.0),
        _hbm_phase("optimizer", 0.016, 115.0),
    ]


def _true_boundaries(phases: list[Phase], anchors: list[float]) -> np.ndarray:
    """Internal phase-edge times given per-step anchor times."""
    offs = np.cumsum([p.duration_s for p in phases])[:-1]
    bounds = [a + o for a in anchors for o in offs]
    bounds += list(anchors[1:])  # step-to-step edges
    return np.array(sorted(bounds))


def _true_energies(phases: list[Phase], steps: int) -> dict[str, float]:
    return {p.name: p.power(V5E) * p.duration_s * steps for p in phases}


def measure_through_sensor(phases: list[Phase], steps: int, seed: int):
    """Play `steps` repeats through the 20 kHz virtual chain with markers.

    Returns (times, watts, anchors, t_end): the decoded ring frames and
    the measured per-step marker times.
    """
    step = render_phases(phases, V5E)
    step_s = float(step.times_s[-1])
    capacity = int(steps * step_s * 20_000 * 1.1) + 8192
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 0.0), seed=seed)
    ps = PowerSensor(dev, ring_capacity=capacity)
    calibrate(ps, {0: 12.0}, n_samples=8000)
    seq0 = ps.ring.head
    dev.firmware.dut.loads[0] = TraceLoad(
        times_s=step.times_s, watts=step.watts, volts=12.0,
        repeat=True, t_offset_s=dev.t_s,
    )
    for _ in range(steps):
        ps.mark("S")
        ps.run_for(step_s)
    ps.mark("E")
    ps.run_for(0.005)
    block = ps.ring.since(seq0)
    anchors = [t for c, t in ps.markers if c == "S"]
    t_end = next(t for c, t in ps.markers if c == "E")
    ps.close()
    return block.times_s, block.watts[:, 0], anchors, t_end


def sample_builtin(phases: list[Phase], steps: int, rate_hz: float):
    """The same workload as a `rate_hz` instant-reading counter sees it."""
    full = render_phases(phases, V5E, repeat=steps)
    meas = BuiltinCounterMeter(mode="instant", update_rate_hz=rate_hz).measure(
        full.times_s, full.watts
    )
    step_s = sum(p.duration_s for p in phases)
    anchors = [k * step_s for k in range(steps)]
    return meas.sample_times_s, meas.sample_watts, anchors, steps * step_s


def evaluate(label, times, watts, anchors, t_end, phases, steps, verbose):
    """Segment + attribute one sampled view; return the error metrics."""
    truth_b = _true_boundaries(phases, anchors)
    truth_e = _true_energies(phases, steps)

    seg = segment_trace(times, watts)
    if seg.boundaries_s.size:
        errs = np.array([abs(seg.nearest_boundary(b) - b) for b in truth_b])
        hit = int(np.sum(errs <= BOUNDARY_TOL_S))
        max_err_ms = float(errs.max() * 1e3)
    else:
        hit, max_err_ms = 0, float("inf")

    spans = timeline_spans(phases, anchors, stretch=True, t_end=t_end)
    ledger = attribute(times, watts, spans)
    errors = {}
    for name, true_j in truth_e.items():
        entry = ledger.entries.get(name)
        errors[name] = abs(entry.energy_j - true_j) / true_j if entry else 1.0
    max_e = max(errors.values())

    print(f"== {label}: {hit}/{len(truth_b)} boundaries within "
          f"{BOUNDARY_TOL_S * 1e3:.0f} ms (max err "
          f"{'inf' if not np.isfinite(max_err_ms) else f'{max_err_ms:.2f}'} ms), "
          f"max per-kernel energy error {max_e * 100.0:.1f}%")
    if verbose:
        print(render_text(ledger, title=f"{label} attributed ledger"))
    emit(f"attrib_{label}_boundary_hits", hit, f"of {len(truth_b)}")
    emit(f"attrib_{label}_max_energy_err_pct", max_e * 100.0, f"{len(truth_e)} kernels")
    return hit, len(truth_b), max_e


def run(steps: int, seed: int, verbose: bool,
        json_path: str | None = None) -> int:
    report = BenchReport("attrib_accuracy", {"steps": steps, "seed": seed})
    phases = build_workload()
    failures = []

    t, w, anchors, t_end = measure_through_sensor(phases, steps, seed)
    hit, total, max_e = evaluate("20khz", t, w, anchors, t_end, phases, steps, verbose)
    report.record("attrib_20khz_boundary_hits", hit, f"of {total}")
    report.record("attrib_20khz_max_energy_err_pct", max_e * 100.0)
    if not report.gate("boundaries_20khz", hit >= total,
                       value=float(hit), limit=float(total),
                       detail=f"phase boundaries within {BOUNDARY_TOL_S * 1e3:.0f} ms"):
        failures.append(f"20 kHz missed {total - hit}/{total} phase boundaries")
    if not report.gate("energy_20khz", max_e <= ENERGY_TOL,
                       value=max_e, limit=ENERGY_TOL,
                       detail="max per-kernel energy error, 20 kHz attribution"):
        failures.append(f"20 kHz energy error {max_e * 100.0:.1f}% > {ENERGY_TOL:.0%}")

    for rate in (100.0, 10.0):
        t, w, anchors, t_end = sample_builtin(phases, steps, rate)
        hit, total, max_e = evaluate(
            f"{rate:.0f}hz", t, w, anchors, t_end, phases, steps, verbose
        )
        report.record(f"attrib_{rate:.0f}hz_boundary_hits", hit, f"of {total}")
        report.record(f"attrib_{rate:.0f}hz_max_energy_err_pct", max_e * 100.0)
        if rate <= 10.0 and not report.gate(
            "builtin_rate_fails", hit < total or max_e > LOW_RATE_FAIL_ERR,
            value=max_e, limit=LOW_RATE_FAIL_ERR,
            detail="10 Hz counter must demonstrably miss the granularity",
        ):
            failures.append(
                "10 Hz counter unexpectedly matched 20 kHz accuracy — "
                "the granularity experiment no longer discriminates"
            )

    report.finish(failures, json_path=json_path)
    if failures:
        for f in failures:
            print(f"FAIL: {f}")
        return 1
    print(f"OK: 20 kHz attribution within spec "
          f"({total} boundaries, ±{BOUNDARY_TOL_S * 1e3:.0f} ms, "
          f"≤{ENERGY_TOL:.0%} energy); builtin-counter rates demonstrably fail")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", action="store_true")
    add_json_arg(ap)
    args = ap.parse_args(argv)
    steps = args.steps if args.steps is not None else (3 if args.smoke else 8)
    return run(steps, args.seed, verbose=not args.quiet, json_path=args.json)


if __name__ == "__main__":
    sys.exit(main())
