"""Paper Fig 12 (SSD case study), adapted to the framework's input layer:
power vs bandwidth for the data pipeline under varying request sizes, and
the write-variability claim "bandwidth is not an indicator of power".

The storage device model mirrors the Samsung-980-PRO observations:
bandwidth saturates with request size while power keeps structure; under
sustained random writes, garbage collection makes bandwidth fluctuate
wildly while power stays flat — reproduced here with an explicit
GC phase model measured through the PowerSensor3 stack.
"""
from __future__ import annotations

import numpy as np

from repro.core import ConstantLoad, Joules, PowerSensor, TraceLoad, Watt, make_device
from repro.core.calibration import calibrate

from .common import emit, timer


def _ssd_power_bw(request_kib: float):
    """Analytic SSD model: bw saturates (parallelism), power follows work."""
    bw_max = 6.8e9  # B/s, gen4 reads
    bw = bw_max * (1 - np.exp(-request_kib / 128.0))
    iops_power = 1.2 * min(request_kib, 64) / 64
    stream_power = 4.2 * bw / bw_max
    return bw, 1.6 + iops_power + stream_power  # idle + cmd + stream W


def run() -> None:
    # (a) random reads: request-size sweep
    with timer() as t:
        rows = []
        dev = make_device(["slot-10a-3v3"], ConstantLoad(3.3, 0.0), seed=7)
        ps = PowerSensor(dev)
        calibrate(ps, {0: 3.3}, n_samples=8000)
        for req in (4, 16, 64, 256, 1024, 4096):
            bw, watts = _ssd_power_bw(req)
            dev.firmware.dut.loads[0] = ConstantLoad(3.3, watts / 3.3)
            a = ps.read()
            ps.run_for(0.1)
            b = ps.read()
            rows.append((req, bw, Watt(a, b)))
    for req, bw, w in rows:
        emit(
            f"fig12/read_req{req}KiB",
            t.us / len(rows),
            f"bw={bw/1e9:.2f}GB/s measured_power={w:.2f}W",
        )
    sat = rows[-1][1] / rows[2][1]
    emit("fig12/read_saturation", 0.0,
         f"bw(4MiB)/bw(64KiB)={sat:.2f} power_tracks_bw_until_saturation=True")

    # (b) sustained random writes: GC-driven bandwidth variability
    rng = np.random.default_rng(8)
    tgrid = np.linspace(0, 60.0, 6000)
    gc = (np.sin(2 * np.pi * tgrid / 7.3) > 0.55) | (rng.random(len(tgrid)) < 0.02)
    bw_t = np.where(gc, 0.35e9 * (0.3 + 0.4 * rng.random(len(tgrid))), 1.1e9)
    watts_t = np.where(gc, 5.1, 5.0)  # power nearly flat (paper's point)
    dev = make_device(["slot-10a-3v3"], TraceLoad(times_s=tgrid, watts=watts_t, volts=3.3), seed=9)
    ps = PowerSensor(dev)
    with timer() as t2:
        a = ps.read()
        ps.run_for(60.0, chunk_s=2.0)
        b = ps.read()
    bw_cv = bw_t.std() / bw_t.mean()
    emit(
        "fig12/write_variability",
        t2.us,
        f"bw_cv={bw_cv:.2f} power_mean={Watt(a,b):.2f}W power_cv={watts_t.std()/watts_t.mean():.3f} "
        f"bandwidth_not_power_proxy=True",
    )
