"""Energy-aware autotuning of the MXU beamformer (the paper's Fig 8 flow).

    PYTHONPATH=src python examples/autotune_energy.py
"""
from repro.kernels.beamformer import tuner_kernel_model
from repro.power import DvfsState, EnergyTuner, fast_sensor_strategy, tuning_speedup


def main():
    kernel = tuner_kernel_model()
    dvfs = DvfsState.sweep(0.6, 1.0, 5)
    tuner = EnergyTuner()
    res = tuner.tune(kernel, fast_sensor_strategy(), dvfs_states=dvfs,
                     max_configs=24, exact_energy=True)
    print(f"evaluated {len(res.records)} (config × clock) points, "
          f"tuning cost {res.total_tuning_time_s:.0f} s (modelled device time)")
    print("Pareto front (TFLOP/s vs TFLOP/J):")
    for r in res.pareto_front():
        print(f"  {r.tflops:7.1f} TFLOP/s  {r.tflop_per_j:5.2f} TFLOP/J  "
              f"clock={r.dvfs_scale:.2f}  {r.config}")
    speedup, fast, slow = tuning_speedup(kernel, max_configs=24, dvfs_states=dvfs)
    print(f"tuning-time vs 10 Hz built-in counter: {speedup:.2f}x faster (paper: 3.25x)")


if __name__ == "__main__":
    main()
