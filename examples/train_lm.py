"""End-to-end driver: train a ~small LM for a few hundred steps with
checkpoint/resume, fault injection and full energy telemetry.

    PYTHONPATH=src python examples/train_lm.py --arch granite-20b --steps 200

This is the example-app face of `repro.launch.train` (same engine).
Crash/resume demo:

    PYTHONPATH=src python examples/train_lm.py --steps 120 --crash-at 60 \
        --ckpt-dir /tmp/lm_ck
    PYTHONPATH=src python examples/train_lm.py --steps 120 --ckpt-dir /tmp/lm_ck
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "qwen2.5-3b", "--steps", "200", "--batch", "8",
                          "--seq", "128", "--log-every", "20"])
