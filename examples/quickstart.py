"""Quickstart: the PowerSensor3 stack + energy-aware training in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import RunConfig, smoke_config
from repro.core import ConstantLoad, Joules, PowerSensor, Watt, make_device, seconds
from repro.core.calibration import calibrate
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.power import EnergyTelemetry, StepCost
from repro.train import LoopConfig, train


def measure_a_rail():
    """1) The faithful layer: measure a 12 V / 8 A load at 20 kHz."""
    dev = make_device(["slot-10a-12v"], ConstantLoad(volts=12.0, amps=0.0), seed=1)
    ps = PowerSensor(dev)
    calibrate(ps, {0: 12.0}, n_samples=8000)  # one-time, §III-D
    dev.firmware.dut.loads[0] = ConstantLoad(volts=12.0, amps=8.0)
    first = ps.read()
    ps.run_for(0.5)  # half a second of simulated streaming
    second = ps.read()
    print(f"[sensor] {Watt(first, second):.2f} W avg, "
          f"{Joules(first, second):.2f} J over {seconds(first, second):.2f} s "
          f"({second.n_samples - first.n_samples} samples @ 20 kHz)")


def train_with_energy_telemetry():
    """2) The adapted layer: train a small LM with J/token telemetry."""
    cfg = smoke_config("qwen2.5-3b")
    model = build_model(cfg, RunConfig(attn_impl="full", remat="none"))
    data = SyntheticTokens(cfg, global_batch=8, seq_len=64, seed=0)
    n = cfg.param_count_estimate()
    tokens_per_step = 8 * 64
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(6.0 * n * tokens_per_step, 12.0 * n, 0.0),
        n_layers=cfg.n_layers,
        useful_flops_per_step=6.0 * n * tokens_per_step,
    )
    result = train(
        model, data,
        AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40),
        LoopConfig(steps=40, log_every=10, ckpt_every=0),
        telemetry=telemetry,
    )
    s = telemetry.summary()
    print(f"[train] loss {result.history[0]['loss']:.3f} -> {result.history[-1]['loss']:.3f}; "
          f"modelled {s['j_per_token']*1e3:.3f} mJ/token on {telemetry.chip.name}")
    check = telemetry.verify_with_sensor(n_steps=3)
    print(f"[cross-check] sensor {check['sensor_joules']:.2f} J vs model "
          f"{check['model_joules']:.2f} J ({check['rel_err']*100:+.2f}%)")


if __name__ == "__main__":
    measure_a_rail()
    train_with_energy_telemetry()
