"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b --requests 8
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "rwkv6-3b", "--requests", "8",
                          "--prompt-len", "32", "--gen-len", "16"])
