"""Live multi-device telemetry with the streaming subsystem: a FleetMonitor
over 8 virtual PowerSensor3 devices running different workloads, queried for
per-device and aggregate windowed stats plus marker-aligned intervals.

    PYTHONPATH=src python examples/fleet_monitor.py
"""
import numpy as np

from repro.core import ConstantLoad, GpuKernelLoad, SquareWaveLoad
from repro.stream import make_virtual_fleet


def main():
    # a heterogeneous rack: steady nodes, a bursty one, a GPU-shaped one
    loads = [ConstantLoad(12.0, 2.0 + i) for i in range(6)]
    loads.append(SquareWaveLoad(12.0, 1.0, 9.0, freq_hz=25.0))
    loads.append(GpuKernelLoad(t_start_s=0.1, ramp_s=0.1, n_phases=3, phase_s=0.3))
    fleet = make_virtual_fleet(loads, seed=42, window_s=0.5)

    fleet.run_for(0.3)
    fleet.mark_all("A")  # bracket a "job" across the whole fleet
    fleet.run_for(0.6)
    fleet.mark_all("B")
    fleet.run_for(0.3)

    snap = fleet.snapshot(window_s=0.5)
    print(f"fleet of {snap.aggregate.n_devices} devices at t={snap.time_s:.2f}s")
    print(f"{'device':>8s} {'mean W':>8s} {'p95 W':>8s} {'peak W':>8s} {'EWMA W':>8s}")
    for name, d in snap.devices.items():
        w = d.window
        print(
            f"{name:>8s} {w.total_mean_w:8.1f} {float(w.pct_w.sum()):8.1f} "
            f"{w.total_peak_w:8.1f} {w.total_ewma_w:8.1f}"
        )
    print(
        f"{'TOTAL':>8s} {snap.aggregate.mean_w:8.1f} {'':>8s} "
        f"{snap.aggregate.peak_w:8.1f} {snap.aggregate.ewma_w:8.1f}"
    )

    print("\njob A->B, attributed per device from the ring buffers:")
    per_dev = fleet.interval("A", "B")
    total = 0.0
    for name, iv in per_dev.items():
        total += iv.total_energy_j
        print(
            f"  {name}: {iv.total_energy_j:7.2f} J over {iv.duration_s*1e3:.0f} ms "
            f"({iv.total_mean_w:.1f} W avg, {iv.n_frames} frames)"
        )
    print(f"  fleet total: {total:.2f} J")
    fleet.close()


if __name__ == "__main__":
    main()
