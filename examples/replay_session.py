"""Record once, replay anywhere: capture a live multi-device session into
a trace archive, then reconstruct it — bit for bit — through the real
host receiver, with no live devices anywhere in sight.

The archive is self-contained (frames as ADC codes, sensor configs with
their calibration tables, the marker stream, firmware version), so the
``.npz`` file is the whole experiment: share it, commit it as a golden,
or re-run any analysis — attribution, windowed stats, fleet power —
months later with identical results.

    PYTHONPATH=src python examples/replay_session.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.attrib import attribute_block, marker_spans
from repro.core import ConstantLoad, SquareWaveLoad
from repro.replay import ReplayFleet, SessionRecorder, TraceArchive
from repro.stream import make_virtual_fleet


def wave_energies(monitor) -> dict[str, list[float]]:
    """Per-device joules of every 'W'-bracketed wave, from the rings."""
    out = {}
    for name in monitor.names:
        ps = monitor[name]
        led = attribute_block(ps.ring.latest(), marker_spans(ps.markers, "W"))
        out[name] = [e.energy_j for e in led.ranked()]
    return out


def main():
    # ---- the live run: two devices, four marker-bracketed waves --------
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 3.0), SquareWaveLoad(12.0, 2.0, 7.0, freq_hz=80.0)],
        seed=7,
        window_s=0.05,
    )
    recorder = SessionRecorder(fleet)
    for _ in range(4):
        fleet.mark_all("W")
        fleet.run_for(0.05)
        recorder.capture()
    fleet.mark_all("W")
    fleet.run_for(0.01)

    path = Path(tempfile.gettempdir()) / "ps3_session.npz"
    archive = recorder.save(path)
    live = wave_energies(fleet)
    live_power = fleet.window_power_w(0.05, poll=False)
    fleet.close()
    print(f"recorded {archive.n_frames} frames over {len(archive)} devices "
          f"-> {path} ({path.stat().st_size} bytes)")

    # ---- anywhere else, any time later: load and replay ----------------
    replay = ReplayFleet(TraceArchive.load(path))
    replay.drain()  # max speed through the *real* host receiver
    replayed = wave_energies(replay.monitor)
    replay_power = replay.monitor.window_power_w(0.05, poll=False)

    print(f"{'device':>8s} {'wave':>5s} {'live J':>12s} {'replayed J':>12s}")
    for name, live_j in live.items():
        for k, (lj, rj) in enumerate(zip(live_j, replayed[name])):
            print(f"{name:>8s} {k:>5d} {lj:>12.6f} {rj:>12.6f}")
            assert abs(rj - lj) <= 1e-9 * abs(lj)
    assert abs(replay_power - live_power) <= 1e-9 * live_power
    print(f"fleet window power: live {live_power:.3f} W == "
          f"replayed {replay_power:.3f} W (bit-identical round trip)")
    replay.close()


if __name__ == "__main__":
    main()
