"""Closed-loop energy-aware serving demo: fleet + power cap + scheduler.

Runs entirely on the virtual sensor stack (no JAX model needed):

1. builds an `OperatingGrid` (DVFS ladder × decode batch) for a small
   serving arch and a `VirtualPlant` of PowerSensor3 devices;
2. drives a `PowerCapGovernor` through an idle → loaded step and prints
   cap adherence scored against the plant's ground-truth log;
3. replays the governed power through an `EnergySloScheduler` round:
   joule-priced admission (energy-fair policy), per-wave measured-energy
   reconciliation, and the per-request J/token table.

    PYTHONPATH=src python examples/governor_serve.py
"""
import numpy as np

from repro.sched import (
    EnergyPricer,
    EnergySloScheduler,
    GovernorConfig,
    OperatingGrid,
    PowerCapGovernor,
    Request,
    VirtualPlant,
    decode_cost_of_batch,
    format_report_rows,
    get_policy,
    settle_time,
    time_over_cap,
)


def main():
    # ---- plant + governor: hold a fleet cap through a load step ----------
    grid = OperatingGrid(
        decode_cost_of_batch(2.0 * 40e6, 2.0 * 40e6, tokens_per_slot_step=8),
        n_layers=4,
        tokens_per_slot_step=8,
    )
    n_dev = 2
    cap_w = 0.72 * n_dev * grid.max_watts
    plant = VirtualPlant(grid, n_devices=n_dev, seed=0)
    gov = PowerCapGovernor(plant, GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0))
    duration, t_step = 0.5, 0.15
    print(f"governing {n_dev} devices under a {cap_w:.0f} W cap "
          f"(uncapped demand ~{n_dev * grid.max_watts:.0f} W)...")
    gov.run(duration, demand_of_t=lambda t: 0 if t < t_step else 32)
    toc = time_over_cap(plant.log, cap_w, 0.0, duration, tol=0.02)
    settle = settle_time(plant.log, cap_w, t_step, duration, tol=0.02)
    pt = plant.point
    print(f"  cap adherence: {toc:.1%} of time over cap, "
          f"settled {settle * 1e3:.0f} ms after the load step")
    print(f"  steady state: batch {pt.batch} @ DVFS {pt.dvfs_scale:.2f} -> "
          f"{plant.true_fleet_w:.0f} W true, "
          f"{pt.tokens_per_s * n_dev / 1e6:.2f} Mtok/s fleet")

    # ---- scheduler: joule-priced waves measured through the same fleet ---
    if pt.tokens_per_s <= 0:  # cap below the lowest active rung: parked
        print("  plant parked at idle; pricing waves at the top grid point")
        pt = grid.best_under(float("inf"))
    j_per_token = pt.j_per_token  # the governed operating point's price
    pricer = EnergyPricer(j_per_token=j_per_token)
    sched = EnergySloScheduler(
        pricer, get_policy("energy-fair"), max_batch=8,
        budget_j=2000.0 * j_per_token,
    )
    rng = np.random.default_rng(0)
    for rid in range(12):
        sched.submit(Request(
            rid=rid, client=f"client{rid % 3}",
            gen_len=int(rng.integers(64, 256)),
        ))
    step_s = 1.0 / pt.tokens_per_s * 8  # 8-token slot step at the point
    print(f"\nscheduling 12 requests (energy-fair, "
          f"budget {sched.budget_j:.3f} J)...")
    while True:
        wave = sched.next_wave()
        if wave is None:
            break
        k = sched.waves[-1].index
        steps = max(r.gen_len for r in wave)
        sched.complete_wave(k, steps)
        # "measure" the wave through the plant's true power at the governed
        # point over the wave's modelled duration
        t_wave = steps * step_s / 8
        sched.reconcile(k, plant.true_fleet_w / n_dev * t_wave)
    print(f"  {len(sched.finished)} finished, {len(sched.rejected)} rejected "
          f"by the joules budget; pricer correction {pricer.correction:.3f}")
    print(format_report_rows(sched.report_rows()))
    plant.close()


if __name__ == "__main__":
    main()
