"""Continuous-mode monitoring of a phased TPU workload, PowerSensor3-style:
20 kHz dump + markers around named step phases, vs the built-in counter.

    PYTHONPATH=src python examples/power_monitor.py
"""
import io

import numpy as np

from repro.power import (
    BuiltinCounterMeter,
    PowerSensor3Meter,
    StepCost,
    V5E,
    phases_for_step,
    render_phases,
)


def main():
    cost = StepCost(flops=3e12, hbm_bytes=8e11, ici_bytes=1.2e11)
    phases = phases_for_step(cost, n_layers=8, overlap_collectives=False)
    tr = render_phases(phases, V5E, idle_before_s=0.02, idle_after_s=0.05, repeat=3)
    print(f"workload: 3 train steps, {tr.duration_s*1e3:.1f} ms, "
          f"{tr.energy_j:.2f} J true energy")

    ps3 = PowerSensor3Meter(seed=0).measure(tr.times_s, tr.watts)
    bi = BuiltinCounterMeter(mode="instant").measure(tr.times_s, tr.watts)
    print(f"powersensor3 : {ps3.energy_j:8.3f} J  ({ps3.energy_error_frac*100:+.2f}%)"
          f"  {len(ps3.sample_times_s)} samples @ 20 kHz")
    print(f"builtin 10Hz : {bi.energy_j:8.3f} J  ({bi.energy_error_frac*100:+.2f}%)"
          f"  {len(bi.sample_times_s)} samples")

    # phase-resolved energy via markers (only possible at 20 kHz)
    marks = tr.phase_marks
    print("per-phase power (PowerSensor3 samples between markers):")
    for (name, t0), (_, t1) in zip(marks[:8], marks[1:9]):
        sel = (ps3.sample_times_s >= t0) & (ps3.sample_times_s < t1)
        if np.any(sel):
            print(f"  {name:>8s}: {ps3.sample_watts[sel].mean():7.1f} W over "
                  f"{(t1-t0)*1e3:6.2f} ms")


if __name__ == "__main__":
    main()
