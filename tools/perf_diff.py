"""Compare perf artifacts across runs.

Two modes:

* dry-run roofline diff (positional, the original mode):

      python tools/perf_diff.py grok1_314b train_4k baseline h1_moesort

* benchmark report diff (``--bench``): compare two ``--json`` documents
  written by any gated benchmark (`benchmarks/common.BenchReport`) and
  print a per-metric delta table plus any gate flips:

      python tools/perf_diff.py --bench old.json new.json
"""
import argparse
import json
import sys

KEYS = [
    ("flops_per_dev", 1e12, "TFLOP/dev"),
    ("hbm_bytes_per_dev", 1e9, "GB/dev"),
    ("coll_bytes_per_dev", 1e9, "GB/dev"),
    ("t_compute_s", 1e-3, "ms"),
    ("t_memory_s", 1e-3, "ms"),
    ("t_collective_s", 1e-3, "ms"),
    ("step_time_s", 1e-3, "ms"),
    ("useful_flops_ratio", 1, ""),
    ("roofline_fraction", 1, ""),
]


def load(arch, shape, tag, mesh="pod16x16"):
    with open(f"experiments/dryrun/{arch}__{shape}__{mesh}__{tag}.json") as f:
        return json.load(f)


def dryrun_diff(argv):
    arch, shape, tag_a, tag_b = argv[:4]
    a = load(arch, shape, tag_a)
    b = load(arch, shape, tag_b)
    ra, rb = a["roofline"], b["roofline"]
    print(f"{arch} × {shape}:  {tag_a}  ->  {tag_b}")
    for k, scale, unit in KEYS:
        va, vb = ra[k], rb[k]
        delta = (vb - va) / va * 100 if va else float("nan")
        print(f"  {k:<22s} {va/scale:12.3f} -> {vb/scale:12.3f} {unit:<9s} ({delta:+.1f}%)")
    print(f"  bottleneck             {ra['bottleneck']:>12s} -> {rb['bottleneck']:>12s}")
    ta, tb = a["memory"].get("temp_size_in_bytes", 0), b["memory"].get("temp_size_in_bytes", 0)
    print(f"  temp_mem_GB            {ta/1e9:12.2f} -> {tb/1e9:12.2f}")
    return 0


def bench_diff(path_a: str, path_b: str) -> int:
    """Delta table between two BenchReport JSON documents.

    Returns nonzero when the newer run regressed: its overall ``ok``
    went false, or any gate that passed before now fails.
    """
    with open(path_a) as fh:
        a = json.load(fh)
    with open(path_b) as fh:
        b = json.load(fh)
    name_a = a.get("benchmark", "?")
    name_b = b.get("benchmark", "?")
    if name_a != name_b:
        print(f"warning: comparing different benchmarks "
              f"({name_a!r} vs {name_b!r})", file=sys.stderr)
    print(f"{name_b}:  {path_a}  ->  {path_b}")

    ma, mb = a.get("metrics", {}), b.get("metrics", {})
    width = max((len(k) for k in set(ma) | set(mb)), default=10)
    for key in sorted(set(ma) | set(mb)):
        if key not in ma:
            print(f"  {key:<{width}}            (new) -> "
                  f"{mb[key]['value']:12.3f}")
            continue
        if key not in mb:
            print(f"  {key:<{width}} {ma[key]['value']:12.3f} -> (gone)")
            continue
        va, vb = ma[key]["value"], mb[key]["value"]
        delta = (vb - va) / va * 100 if va else float("nan")
        note = mb[key].get("derived", "")
        print(f"  {key:<{width}} {va:12.3f} -> {vb:12.3f} ({delta:+8.1f}%)"
              f"{'  ' + note if note else ''}")

    ga = {g["name"]: g for g in a.get("gates", [])}
    gb = {g["name"]: g for g in b.get("gates", [])}
    regressions = []
    for key in sorted(set(ga) | set(gb)):
        pa = ga.get(key, {}).get("passed")
        pb = gb.get(key, {}).get("passed")
        if pa == pb and pb is not False:
            continue
        mark = {True: "ok", False: "FAIL", None: "-"}
        print(f"  gate {key:<{max(width - 5, 1)}} {mark[pa]:>12} -> {mark[pb]}")
        if pa is not False and pb is False:
            regressions.append(key)
    for f in b.get("failures", []):
        print(f"  failure: {f}")

    ok_a, ok_b = a.get("ok", True), b.get("ok", True)
    if regressions or (ok_a and not ok_b):
        print(f"REGRESSION: {', '.join(regressions) or 'overall ok -> failed'}")
        return 1
    print(f"ok: {'pass' if ok_b else 'still failing'} "
          f"(was {'pass' if ok_a else 'failing'})")
    return 0


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "--bench":
        ap = argparse.ArgumentParser(prog="perf_diff --bench")
        ap.add_argument("baseline", help="older BenchReport JSON")
        ap.add_argument("candidate", help="newer BenchReport JSON")
        args = ap.parse_args(argv[1:])
        return bench_diff(args.baseline, args.candidate)
    return dryrun_diff(argv)


if __name__ == "__main__":
    sys.exit(main())
