"""Compare two dry-run JSONs (baseline vs hillclimb iteration).

    python tools/perf_diff.py grok1_314b train_4k baseline h1_moesort
"""
import json
import sys

KEYS = [
    ("flops_per_dev", 1e12, "TFLOP/dev"),
    ("hbm_bytes_per_dev", 1e9, "GB/dev"),
    ("coll_bytes_per_dev", 1e9, "GB/dev"),
    ("t_compute_s", 1e-3, "ms"),
    ("t_memory_s", 1e-3, "ms"),
    ("t_collective_s", 1e-3, "ms"),
    ("step_time_s", 1e-3, "ms"),
    ("useful_flops_ratio", 1, ""),
    ("roofline_fraction", 1, ""),
]


def load(arch, shape, tag, mesh="pod16x16"):
    with open(f"experiments/dryrun/{arch}__{shape}__{mesh}__{tag}.json") as f:
        return json.load(f)


def main():
    arch, shape, tag_a, tag_b = sys.argv[1:5]
    a = load(arch, shape, tag_a)
    b = load(arch, shape, tag_b)
    ra, rb = a["roofline"], b["roofline"]
    print(f"{arch} × {shape}:  {tag_a}  ->  {tag_b}")
    for k, scale, unit in KEYS:
        va, vb = ra[k], rb[k]
        delta = (vb - va) / va * 100 if va else float("nan")
        print(f"  {k:<22s} {va/scale:12.3f} -> {vb/scale:12.3f} {unit:<9s} ({delta:+.1f}%)")
    print(f"  bottleneck             {ra['bottleneck']:>12s} -> {rb['bottleneck']:>12s}")
    ta, tb = a["memory"].get("temp_size_in_bytes", 0), b["memory"].get("temp_size_in_bytes", 0)
    print(f"  temp_mem_GB            {ta/1e9:12.2f} -> {tb/1e9:12.2f}")


if __name__ == "__main__":
    main()
