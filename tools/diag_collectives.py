import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Diagnostic: per-collective breakdown of a cell's sharded L=1 lowering.

    PYTHONPATH=src python tools/diag_collectives.py chameleon-34b train_4k [overrides...]
"""
import re
import sys
from dataclasses import replace

import jax

from repro.configs import ALIASES, SHAPES, get_config
from repro.launch import mesh as mesh_lib
from repro.launch.components import _reduced_cfgs, _step_fn_and_args
from repro.launch.roofline import _bytes_of_shape, _COLL_RE, _GROUPS_RE
from repro.launch.specs import default_run_config


def main():
    arch = ALIASES.get(sys.argv[1], sys.argv[1])
    shape = SHAPES[sys.argv[2]]
    cfg = get_config(arch)
    run = default_run_config(shape.kind)
    for kv in sys.argv[3:]:
        k, v = kv.split("=")
        run = replace(run, **{k: (v if not v.isdigit() else int(v))
                              if v not in ("True", "False") else v == "True"})
    c1, c2, mult = _reduced_cfgs(cfg)
    import os
    if os.environ.get('DIAG_L2'):
        c1 = c2
    mesh = mesh_lib.make_production_mesh()
    fn, args = _step_fn_and_args(c1, shape, replace(run, scan_layers=False), mesh=mesh)
    txt = jax.jit(fn).lower(*args).compile().as_text()
    rows = []
    for line in txt.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        size = _bytes_of_shape(m.group(1))
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 1
        op = re.search(r'op_name="([^"]*)"', line)
        rows.append((size * (g - 1) / g * (2 if m.group(2) == "all-reduce" else 1),
                     m.group(2), g, (op.group(1) if op else "")[:110]))
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"total wire bytes (L=1 module): {total/1e9:.2f} GB/dev; multiplier ~{mult}")
    for wire, kind, g, name in rows[:25]:
        print(f"{wire/1e9:9.3f} GB  {kind:<18s} g={g:<3d} {name}")


if __name__ == "__main__":
    main()
