"""Regenerate (or verify) the committed golden trace corpus.

    PYTHONPATH=src python tools/regen_goldens.py            # rewrite goldens
    PYTHONPATH=src python tools/regen_goldens.py --check    # CI staleness gate
    PYTHONPATH=src python tools/regen_goldens.py --scenario chaos-dropout

Default output directory is ``tests/goldens`` (the committed corpus).

``--check`` re-records every scenario live *and* replays the committed
archives, comparing both against the committed tolerance manifest — it
exits nonzero when the corpus has gone stale relative to the code (or the
code relative to the corpus), which is exactly the regression the golden
CI job gates.  Regeneration itself enforces the subsystem's round-trip
invariant (live ≡ replay within 1e-9) and the < 200 kB mini-corpus
budget before writing anything the repo would commit.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.replay.golden import (  # noqa: E402  (path bootstrap above)
    SCENARIOS,
    check_goldens,
    corpus_bytes,
    default_golden_dir,
    write_goldens,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, metavar="DIR",
                    help="golden directory (default: tests/goldens)")
    ap.add_argument("--check", action="store_true",
                    help="verify the committed corpus instead of rewriting it")
    ap.add_argument("--scenario", action="append", choices=sorted(SCENARIOS),
                    help="limit to one scenario (repeatable)")
    args = ap.parse_args(argv)
    golden_dir = Path(args.out) if args.out else default_golden_dir()

    if args.check:
        errors = check_goldens(golden_dir, names=args.scenario, rerecord=True)
        if errors:
            print(f"STALE GOLDENS ({len(errors)} violations):")
            for e in errors:
                print(f"  - {e}")
            print("regenerate with: PYTHONPATH=src python tools/regen_goldens.py")
            return 1
        print(f"golden corpus at {golden_dir} is fresh "
              f"({corpus_bytes(golden_dir)} bytes, "
              f"{len(args.scenario or SCENARIOS)} scenarios)")
        return 0

    manifest = write_goldens(golden_dir, names=args.scenario)
    total = corpus_bytes(golden_dir)
    n_written = len(args.scenario or SCENARIOS)
    print(f"recorded {n_written} golden scenario(s) into {golden_dir}; "
          f"manifest now pins {len(manifest['scenarios'])} ({total} bytes total):")
    for name, entry in manifest["scenarios"].items():
        size = (golden_dir / entry["archive"]).stat().st_size
        print(f"  {name:20s} {size:7d} B  {len(entry['metrics'])} pinned metrics")
    return 0


if __name__ == "__main__":
    sys.exit(main())
